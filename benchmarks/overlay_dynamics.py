"""Overlay dynamics — incremental NetworkPlan sync vs full recompile.

Exercises the live-overlay path (docs/OVERLAY.md) on a hierarchical
overlay (100k peers in the full run, 20k in ``--fast``):

* **single leave / join**: one `remove_peer` (with the "reconnect"
  repair) or one `add_peer`, then `plan.sync()` is timed against
  building a from-scratch `NetworkPlan` warmed on the same cached
  origins.  The ISSUE-9 acceptance criterion — sync >= 5x faster than
  the rebuild AND bit-exact with the rebuilt plan's query results on
  the scalar reference, the numpy sweep, and the jitted jax sweep, in
  both the shared and independent RNG modes — is asserted IN-BENCH
  (the run exits non-zero on violation) and re-enforced by the gate.
* **churn-rate sweep**: batches of join/leave events between syncs
  (`random_session` + "reconnect" repair), measuring how the
  incremental speedup decays as more cached BFS trees are invalidated
  per sync.  Floor: incremental must at least beat the rebuild (1x).
* **replication sweep**: top-k recall (accuracy) and the retrieval
  message/byte counts vs `SimParams.replication_factor` under heavy
  churn, with the numpy/jax/reference parity bit per row.

  PYTHONPATH=src python -m benchmarks.overlay_dynamics [--fast] [--out P]

writes ``BENCH_overlay_dynamics.json`` with suites
``overlay_dynamics`` (speedup floor 5x + parity), ``overlay_churn``
(floor 1x + parity) and ``overlay_replication`` (parity-only), all
gated by ``benchmarks/regression_gate.py`` against
``benchmarks/baselines/BENCH_overlay_dynamics.fast.json``.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.engine import (NetworkPlan, Overlay, QuerySpec, SimEngine,
                          get_policy)
from repro.p2psim import SimParams, barabasi_albert, build_topology
from repro.p2psim.graph import bfs_tree_csr
from repro.p2psim.overlay import apply_events, random_session
from repro.p2psim.simulate import run_query_reference

_PARITY_FIELDS = ("m_fw", "m_bw", "m_rt", "b_bw", "b_rt",
                  "response_time_s", "accuracy")
_STRATEGY = "st1+2"


def _warm(plan: NetworkPlan, origins) -> None:
    """Compile statics + DepthSlices for ``origins`` (what a standing
    server holds for its hot query set)."""
    sts, _ = plan.origin_statics(np.asarray(origins, np.int64), 0,
                                 _STRATEGY)
    for st in sts:
        plan.depth_slices(st)


def _rebuild_s(ov: Overlay, origins) -> float:
    """Wall time for the from-scratch path: new plan + same warm set."""
    t0 = time.perf_counter()
    fresh = NetworkPlan(ov.top)
    _warm(fresh, origins)
    return time.perf_counter() - t0, fresh


def _parity(synced: NetworkPlan, fresh: NetworkPlan, top, origins,
            params, *, jax_too: bool) -> bool:
    """Synced-plan results == rebuilt-plan results == the scalar
    reference, numpy (+ optionally jax), shared + independent modes."""
    pol = get_policy("fd-dynamic").variant(lifetime_mean_s=30.0)
    engines = [SimEngine(fresh, params)]
    if jax_too:
        engines += [SimEngine(synced, params, backend="jax"),
                    SimEngine(fresh, params, backend="jax")]
    base_eng = SimEngine(synced, params)
    for rng in ("shared", "independent"):
        spec = QuerySpec(origins=tuple(origins), n_trials=1, rng=rng)
        base = base_eng.run(spec, pol).metrics
        for eng in engines:
            got = eng.run(spec, pol).metrics
            if not all(np.array_equal(getattr(base, f), getattr(got, f))
                       for f in _PARITY_FIELDS):
                return False
    ref, _ = run_query_reference(top, int(origins[0]), params,
                                 dynamic=True, lifetime_mean_s=30.0)
    one = base_eng.run(QuerySpec(origins=(int(origins[0]),)), pol)
    return one.query_metrics(0, 0) == ref


def _deep_leaf(plan: NetworkPlan, origin: int) -> int:
    """A degree-1 peer as deep as possible below ``origin`` — the
    common 'edge-of-the-network peer departs' case."""
    _, depth, _ = bfs_tree_csr(plan.indptr, plan.indices, origin,
                               plan.top.n)
    cand = np.where(plan.degrees == 1, depth, -1)
    if cand.max() < 1:                      # no leaves: deepest low-degree
        cand = np.where(plan.degrees <= 2, depth, -1)
    return int(cand.argmax())


def incremental_sync_rows(fast: bool):
    """Single leave / join on the big hierarchical overlay."""
    n_peers = 20_000 if fast else 100_000
    n_origins = 8 if fast else 16
    params = SimParams(seed=0)
    rows = []
    for event in ("leave", "join"):
        top = build_topology("hierarchical", n_peers, seed=7)
        ov = Overlay(top)
        plan = NetworkPlan(ov)
        rng = np.random.default_rng(11)
        origins = sorted(int(o) for o in
                         rng.choice(n_peers, n_origins, replace=False))
        _warm(plan, origins)
        if event == "leave":
            ov.remove_peer(_deep_leaf(plan, origins[0]),
                           repair="reconnect")
        else:
            nbs = (origins[0], int(ov.top.neighbors[origins[0]][0]))
            ov.add_peer(neighbors=nbs)
        t0 = time.perf_counter()
        assert plan.sync() is True
        sync_s = time.perf_counter() - t0
        rebuild_s, fresh = _rebuild_s(ov, origins)
        speedup = rebuild_s / max(sync_s, 1e-9)
        parity = _parity(plan, fresh, ov.top, origins[:2], params,
                         jax_too=True)
        row = {"suite": "overlay_dynamics", "event": event,
               "n_peers": n_peers, "n_cached_origins": n_origins,
               "sync_s": round(sync_s, 4),
               "rebuild_s": round(rebuild_s, 4),
               "speedup": round(speedup, 2), "parity": parity}
        print(f"[overlay_dynamics] {event:<5s} n={n_peers}  "
              f"sync {sync_s*1e3:8.1f} ms  rebuild {rebuild_s*1e3:8.1f} "
              f"ms  speedup {speedup:6.2f}x  parity={parity}")
        rows.append(row)
        # ISSUE-9 acceptance: >= 5x and bit-exact, asserted in-bench
        assert speedup >= 5.0, (
            f"incremental sync after a single {event} is only "
            f"{speedup:.2f}x faster than a full rebuild (need >= 5x)")
        assert parity, f"synced plan diverged from rebuild after {event}"
    return rows


def churn_sweep_rows(fast: bool):
    """Speedup decay as more events land between syncs."""
    n_peers = 20_000 if fast else 100_000
    n_origins = 8 if fast else 16
    params = SimParams(seed=0)
    top = build_topology("hierarchical", n_peers, seed=7)
    ov = Overlay(top)
    plan = NetworkPlan(ov)
    rng = np.random.default_rng(13)
    origins = sorted(int(o) for o in
                     rng.choice(n_peers, n_origins, replace=False))
    _warm(plan, origins)
    rows = []
    for i, events_per_sync in enumerate((2, 8, 32)):
        events = random_session(ov, events_per_sync, seed=100 + i,
                                join_prob=0.5)
        apply_events(ov, events, repair="reconnect")
        t0 = time.perf_counter()
        assert plan.sync() is True
        sync_s = time.perf_counter() - t0
        rebuild_s, fresh = _rebuild_s(ov, origins)
        speedup = rebuild_s / max(sync_s, 1e-9)
        parity = _parity(plan, fresh, ov.top, origins[:2], params,
                         jax_too=False)
        row = {"suite": "overlay_churn",
               "events_per_sync": events_per_sync, "n_peers": n_peers,
               "n_cached_origins": n_origins,
               "sync_s": round(sync_s, 4),
               "rebuild_s": round(rebuild_s, 4),
               "speedup": round(speedup, 2), "parity": parity}
        print(f"[overlay_churn] events={events_per_sync:<3d} "
              f"sync {sync_s*1e3:8.1f} ms  rebuild {rebuild_s*1e3:8.1f} "
              f"ms  speedup {speedup:6.2f}x  parity={parity}")
        rows.append(row)
        assert parity, "synced plan diverged from rebuild under churn"
    return rows


def replication_rows(fast: bool):
    """Top-k recall / retrieval traffic vs replication factor under
    heavy churn (mean peer lifetime ~ the query horizon)."""
    n_peers = 2_000 if fast else 10_000
    top = barabasi_albert(n_peers, m=2, seed=5)
    pol = get_policy("fd-dynamic").variant(lifetime_mean_s=8.0)
    spec = QuerySpec(origins=(0, 7, 101, 999), n_trials=4,
                     rng="independent")
    rows = []
    for r, placement in ((0, "random"), (2, "random"), (4, "random"),
                         (2, "neighbor")):
        params = SimParams(seed=3, replication_factor=r,
                           replication_placement=placement)
        m_np = SimEngine(top, params).run(spec, pol).metrics
        m_jx = SimEngine(top, params, backend="jax").run(spec,
                                                         pol).metrics
        parity = all(np.array_equal(getattr(m_np, f), getattr(m_jx, f))
                     for f in _PARITY_FIELDS)
        ref, _ = run_query_reference(top, 0, params, dynamic=True,
                                     lifetime_mean_s=8.0)
        one = SimEngine(top, params).run(
            QuerySpec(origins=(0,)), pol)
        parity = parity and one.query_metrics(0, 0) == ref
        row = {"suite": "overlay_replication", "replication_factor": r,
               "placement": placement, "n_peers": n_peers,
               "recall": round(float(m_np.accuracy.mean()), 4),
               "m_rt": round(float(m_np.m_rt.mean()), 2),
               "b_rt": round(float(m_np.b_rt.mean()), 1),
               "m_bw": round(float(m_np.m_bw.mean()), 2),
               "parity": parity}
        print(f"[overlay_replication] r={r} {placement:<9s} "
              f"recall {row['recall']:.3f}  m_rt {row['m_rt']:8.1f}  "
              f"parity={parity}")
        rows.append(row)
        assert parity, f"replication r={r}/{placement} broke parity"
    base = next(x for x in rows if x["replication_factor"] == 0)
    best = max(x["recall"] for x in rows if x["replication_factor"] > 0)
    assert best >= base["recall"], \
        "replication failed to recover recall under churn"
    return rows


def collect(fast: bool = False) -> dict:
    rows = (incremental_sync_rows(fast) + churn_sweep_rows(fast)
            + replication_rows(fast))
    return {
        "meta": {"created_unix": time.time(), "fast": fast,
                 "numpy": np.__version__},
        "results": rows,
    }


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke sizes (gate against the committed "
                         "fast baseline)")
    ap.add_argument("--out", default="BENCH_overlay_dynamics.json")
    args = ap.parse_args()
    data = collect(fast=args.fast)
    with open(args.out, "w") as f:
        json.dump(data, f, indent=1)
    print(f"wrote {args.out} ({len(data['results'])} rows)")


if __name__ == "__main__":
    main()
