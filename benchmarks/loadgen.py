"""Serving load generator — QueryServer under ramping concurrent load.

Drives a warm :class:`repro.engine.QueryServer` with mixed
policy/topology request streams at ramping concurrency (closed-loop
client threads, topping out at >= 64 in-flight requests even in
``--fast``), and measures the serving numbers the paper's deployment
story rests on: sustained throughput, p50/p95/p99 latency, and how much
dynamic batching actually coalesced.  Every stage also replays its
request list through one-at-a-time ``Engine.run()`` calls and asserts
the served results are entry-wise BIT-EXACT — the batcher must change
scheduling, never bits — across every policy and RNG mode in the mix
(shared batch-of-1, independent streams, explicit seed grids, and
non-coalescable shared multi-entry specs).

  PYTHONPATH=src python -m benchmarks.loadgen [--fast] [--out PATH]

writes ``BENCH_serving.json``:

  {
    "meta":    {"created_unix": float, "fast": bool, "numpy": str},
    "results": [
      {"suite": "serving", "backend": "numpy"|"jax", "concurrency": int,
       "n_requests": int, "n_engines": int, "n_policies": int,
       "wall_s": float, "throughput_qps": float, "p50_ms": float,
       "p95_ms": float, "p99_ms": float, "mean_batch": float,
       "max_batch": int, "batched_frac": float, "shed": int,
       "timed_out": int, "parity": bool, "batched": bool}
    ]
  }

``parity`` (bit-exact vs sequential ``run()``) and ``batched`` (fusion
> 1 actually occurred) are required bits; ``throughput_qps`` carries an
absolute floor — all enforced by ``benchmarks/regression_gate.py``
against ``benchmarks/baselines/BENCH_serving.fast.json`` (see
docs/SERVING.md for reading these rows).
"""
from __future__ import annotations

import json
import threading
import time

import numpy as np

from repro.engine import (QueryServer, QuerySpec, ServerConfig, SimEngine,
                          ServerError)
from repro.p2psim import SimParams, build_topology

POLICIES = ("fd-dynamic", "cn", "cn-star", "fd-st1+2")
TOPOLOGIES = ("ba", "small-world")
_PARITY_FIELDS = ("n_reached", "n_edges_pq", "m_fw", "m_bw", "m_rt",
                  "b_fw", "b_bw", "b_rt", "response_time_s", "accuracy")


def _mixed_requests(n: int, n_peers: int, engine_names, policies, seed=0):
    """A request stream covering every RNG mode and both batcher paths.

    Cycles through shared batch-of-1, independent multi-entry, explicit
    seed-grid (all coalescable) and shared multi-entry (runs solo)
    specs, with policies and engines assigned round-robin.
    """
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        o = int(rng.integers(n_peers))
        o2 = int(rng.integers(n_peers))
        s = int(rng.integers(1 << 30))
        kind = i % 4
        if kind == 0:          # shared stream, batch of 1 (coalesces)
            spec = QuerySpec(origins=(o,), seed=s)
        elif kind == 1:        # independent streams (coalesces)
            spec = QuerySpec(origins=(o, o2), n_trials=2,
                             rng="independent", seed=s)
        elif kind == 2:        # explicit seed grid (coalesces)
            spec = QuerySpec(origins=(o,), n_trials=2,
                             seeds=[[s, s + 1]])
        else:                  # shared multi-entry (must run solo)
            spec = QuerySpec(origins=(o, o2), n_trials=2, seed=s)
        reqs.append((spec, policies[i % len(policies)],
                     engine_names[i % len(engine_names)]))
    return reqs


def _metrics_equal(a, b) -> bool:
    return all(np.array_equal(getattr(a, f), getattr(b, f))
               for f in _PARITY_FIELDS)


def _closed_loop(server, reqs, concurrency: int):
    """Run ``reqs`` through ``server`` with ``concurrency`` client
    threads; returns (results, per-request latencies, wall seconds,
    server errors)."""
    results = [None] * len(reqs)
    lat = [0.0] * len(reqs)
    errors = []
    cursor = {"i": 0}
    lock = threading.Lock()

    def client():
        while True:
            with lock:
                i = cursor["i"]
                if i >= len(reqs):
                    return
                cursor["i"] = i + 1
            spec, pol, name = reqs[i]
            t0 = time.perf_counter()
            try:
                results[i] = server.query(spec, pol, engine=name)
            except ServerError as e:
                errors.append((i, e))
            lat[i] = time.perf_counter() - t0

    t0 = time.perf_counter()
    threads = [threading.Thread(target=client)
               for _ in range(concurrency)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return results, lat, time.perf_counter() - t0, errors


def _stage_row(engines, reqs, concurrency: int, backend: str,
               n_policies: int, max_batch: int = 64) -> dict:
    """One ramp stage: serve ``reqs``, then replay sequentially for the
    bit-exactness bit."""
    server = QueryServer(engines, ServerConfig(
        max_queue=max(256, 2 * concurrency), max_batch=max_batch,
        batch_window_s=0.002))
    with server:
        results, lat, wall, errors = _closed_loop(server, reqs,
                                                  concurrency)
        m = server.metrics()
    if errors:                        # nothing should shed at this bound
        raise AssertionError(f"{len(errors)} requests failed: "
                             f"{errors[0][1]!r}")
    parity = True
    for (spec, pol, name), res in zip(reqs, results):
        ref = engines[name].run(spec, pol)
        if not _metrics_equal(res.metrics, ref.metrics):
            parity = False
            break
    hist = m.batch_hist
    n_hist = sum(hist.values())
    batched_frac = (sum(c for s, c in hist.items() if s > 1)
                    / max(n_hist, 1))
    lat_ms = np.asarray(lat) * 1e3
    return {
        "suite": "serving", "backend": backend,
        "concurrency": concurrency, "n_requests": len(reqs),
        "n_engines": len(engines), "n_policies": n_policies,
        "wall_s": round(wall, 4),
        "throughput_qps": round(len(reqs) / max(wall, 1e-9), 2),
        "p50_ms": round(float(np.percentile(lat_ms, 50)), 3),
        "p95_ms": round(float(np.percentile(lat_ms, 95)), 3),
        "p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
        "mean_batch": round(m.mean_batch, 3),
        "max_batch": int(m.max_batch),
        "batched_frac": round(batched_frac, 3),
        "shed": m.shed, "timed_out": m.timed_out,
        "retraced": sum(1 for r in results
                        if r is not None and r.compile_s > 0),
        "parity": parity, "batched": m.max_batch > 1,
    }


def serving_sweep(fast: bool = False):
    """The ramp: mixed-stream stages at growing concurrency (numpy),
    plus a shape-stable jax-backend batching-parity stage."""
    results = []
    n_peers = 400 if fast else 1000
    policies = POLICIES[:3] if fast else POLICIES
    engines = {name: SimEngine(build_topology(name, n_peers, seed=7),
                               SimParams(seed=0))
               for name in TOPOLOGIES}
    names = sorted(engines)
    for name in names:
        for pol in policies:          # warm plans before taking load
            engines[name].run(QuerySpec(origins=(0,)), pol)
    stages = ((8, 64), (32, 128), (64, 192)) if fast else \
        ((8, 128), (16, 256), (32, 384), (64, 512), (128, 768))
    for concurrency, n_requests in stages:
        reqs = _mixed_requests(n_requests, n_peers, names, policies,
                               seed=concurrency)
        row = _stage_row(engines, reqs, concurrency, "numpy",
                         len(policies))
        print(f"[serving] numpy c={concurrency:<4d} "
              f"{row['throughput_qps']:>8.1f} qps  p50/p95/p99 "
              f"{row['p50_ms']:.1f}/{row['p95_ms']:.1f}/"
              f"{row['p99_ms']:.1f} ms  mean batch {row['mean_batch']:.2f}"
              f"  parity={row['parity']}")
        results.append(row)
        assert row["parity"], "served results diverged from run()"
        assert row["batched"], "dynamic batching never fused requests"
    # jax stage: jitted sweeps are trace-cached per (origin statics,
    # entry-bucket) — entry batches pad to power-of-two buckets, so
    # pre-warming each served origin at batch sizes (1, 2, 4) via
    # QueryServer.warm covers EVERY fused dispatch shape max_batch=4
    # can produce.  Live dispatches must then retrace nothing
    # (asserted: retraced == 0, i.e. compile_s == 0 on every request).
    jax_c, jax_n = (8, 32) if fast else (16, 96)
    jax_engines = {"ba": SimEngine(build_topology("ba", n_peers, seed=7),
                                   SimParams(seed=0), backend="jax")}
    rng = np.random.default_rng(1)
    pool = tuple(int(x) for x in rng.choice(n_peers, 4, replace=False))
    reqs = [(QuerySpec(origins=(pool[i % len(pool)],),
                       seed=int(rng.integers(1 << 30))),
             "fd-dynamic", "ba") for i in range(jax_n)]
    warm_srv = QueryServer(jax_engines)
    for o in pool:                               # trace every bucket
        warm_srv.warm(QuerySpec(origins=(o,), seed=1), "fd-dynamic",
                      batch_sizes=(1, 2, 4))
    row = _stage_row(jax_engines, reqs, jax_c, "jax", 1, max_batch=4)
    print(f"[serving] jax   c={jax_c:<4d} {row['throughput_qps']:>8.1f} "
          f"qps  mean batch {row['mean_batch']:.2f}  "
          f"parity={row['parity']} batched={row['batched']} "
          f"retraced={row['retraced']}")
    assert row["parity"], "jax served results diverged from run()"
    assert row["retraced"] == 0, \
        "warmed buckets still retraced at dispatch"
    results.append(row)
    return results


def collect(fast: bool = False) -> dict:
    rows = serving_sweep(fast)
    return {
        "meta": {"created_unix": time.time(), "fast": fast,
                 "numpy": np.__version__},
        "results": rows,
    }


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke sizes (gate against the committed "
                         "fast baseline)")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()
    data = collect(fast=args.fast)
    with open(args.out, "w") as f:
        json.dump(data, f, indent=2)
    print(f"wrote {args.out} ({len(data['results'])} rows)")


if __name__ == "__main__":
    main()
