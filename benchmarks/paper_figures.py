"""One benchmark per paper table/figure (§5).  Each returns rows of
(name, value, derived) and the runner prints CSV + a verdict against the
paper's claims.

  fig2  response time vs peers on a 64-node 'cluster' (1 Gbps, ~0 lat)
  fig3  response time vs peers: FD vs CN vs CN* (WAN params, Table 1)
  fig4  response time vs bandwidth
  fig5  response time vs latency
  fig6  communication cost vs peers: FD-Basic / FD-Str1 / FD-Str1+2
  fig7  statistics heuristic: accuracy + comm reduction vs z
  fig8  accuracy vs peer lifetime: FD-Basic vs FD-Dynamic
  lemmas  exact message-count checks (Lemmas 1-3, Thm 1, §3.2 bytes)
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.engine import (QuerySpec, SimEngine, get_policy,
                          policy_from_legacy)
from repro.p2psim import SimParams, barabasi_albert
from repro.p2psim.graph import eccentricity_ttl

WAN = SimParams(seed=0)
CLUSTER = SimParams(seed=0, latency_mean_s=0.0005, latency_var=1e-8,
                    bw_mean_Bps=125e6, bw_var=1.0)


def _top(n, seed=0):
    return barabasi_albert(n, m=2, seed=seed)


def _run(engine, origin, params=None, **legacy):
    """One query via the engine API; returns its ``QueryMetrics``.

    ``legacy`` holds the old run_query knobs (algorithm / strategy /
    dynamic / lifetime_mean_s) mapped onto a registry policy.  Reusing
    one ``engine`` per topology amortizes the compiled ``NetworkPlan``
    across every policy a figure sweeps.
    """
    pol = policy_from_legacy(
        legacy.pop("algorithm", "fd"), legacy.pop("strategy", "st1+2"),
        legacy.pop("dynamic", True),
        legacy.pop("lifetime_mean_s", float("inf")))
    assert not legacy, f"unknown knobs: {legacy}"
    res = engine.run(QuerySpec(origins=(int(origin),)), pol, params=params)
    return res.metrics.query_metrics(0, 0)


def fig2_cluster_scaleup():
    rows = []
    for n in (8, 16, 32, 64):
        met = _run(SimEngine(_top(n), CLUSTER), 0)
        rows.append((f"fig2/resp_s/n={n}", met.response_time_s, "fd-cluster"))
    # paper: logarithmic scale-up -> resp(64)/resp(8) well below 64/8
    r8 = rows[0][1]
    r64 = rows[-1][1]
    rows.append(("fig2/scaleup_ratio_64_over_8", r64 / max(r8, 1e-9),
                 "log-like<2 (paper: logarithmic)"))
    return rows


def fig3_scaleup_vs_baselines():
    rows = []
    for n in (100, 500, 1000, 2500, 5000):
        eng = SimEngine(_top(n), WAN)
        for alg in ("fd", "cn", "cn_star"):
            met = _run(eng, 0, algorithm=alg)
            rows.append((f"fig3/resp_s/{alg}/n={n}", met.response_time_s,
                         "paper: FD lowest, gap grows with n"))
    return rows


def fig4_bandwidth():
    rows = []
    eng = SimEngine(_top(1000), WAN)
    for kbps in (28, 56, 112, 256, 1024):
        p = dataclasses.replace(WAN, bw_mean_Bps=kbps * 1000 / 8,
                                bw_var=(kbps * 250 / 8) ** 2)
        for alg in ("fd", "cn", "cn_star"):
            met = _run(eng, 0, p, algorithm=alg)
            rows.append((f"fig4/resp_s/{alg}/bw={kbps}kbps",
                         met.response_time_s,
                         "paper: resp falls with bw; FD lowest"))
    return rows


def fig5_latency():
    rows = []
    eng = SimEngine(_top(1000), WAN)
    for ms in (50, 200, 500, 1000, 2000):
        p = dataclasses.replace(WAN, latency_mean_s=ms / 1000,
                                latency_var=(ms / 2000) ** 2)
        for alg in ("fd", "cn", "cn_star"):
            met = _run(eng, 0, p, algorithm=alg)
            rows.append((f"fig5/resp_s/{alg}/lat={ms}ms",
                         met.response_time_s,
                         "paper: latency hits FD harder than CN; "
                         "FD still lowest"))
    return rows


def fig6_comm_cost():
    rows = []
    for n in (500, 1000, 2500, 5000, 10000):
        eng = SimEngine(_top(n), WAN)
        vals = {}
        for strat in ("basic", "st1", "st1+2"):
            met = _run(eng, 0, strategy=strat, dynamic=False)
            vals[strat] = met.total_bytes
            rows.append((f"fig6/bytes/{strat}/n={n}", met.total_bytes,
                         "paper@10k: basic~5MB, str1+2~3.5MB (~30% cut)"))
        rows.append((f"fig6/reduction/n={n}",
                     1 - vals["st1+2"] / vals["basic"],
                     "paper: ~0.30"))
    return rows


def fig7_statistics():
    rows = []
    eng = SimEngine(_top(1000), WAN)
    for z in (0.0, 0.2, 0.4, 0.6, 0.8, 1.0):
        ex = eng.run(QuerySpec(origins=(0,)),
                     get_policy("fd-stats").variant(z=z)).extras
        red, acc = ex["comm_reduction"], ex["accuracy"]
        rows.append((f"fig7/accuracy/z={z}", acc,
                     "paper: z=0.8 -> acc>0.90"))
        rows.append((f"fig7/comm_reduction/z={z}", red,
                     "paper: z=0.8 -> ~0.35 cut"))
    return rows


def fig8_dynamicity():
    rows = []
    eng = SimEngine(_top(1000), WAN)
    for lt_min in (0.5, 1, 2, 4, 15, 60):
        accs_b, accs_d = [], []
        for seed in range(3):
            p = dataclasses.replace(WAN, seed=seed)
            mb = _run(eng, 0, p, dynamic=False,
                      lifetime_mean_s=lt_min * 60)
            md = _run(eng, 0, p, dynamic=True,
                      lifetime_mean_s=lt_min * 60)
            accs_b.append(mb.accuracy)
            accs_d.append(md.accuracy)
        rows.append((f"fig8/acc_basic/lifetime={lt_min}min",
                     float(np.mean(accs_b)), "paper: <1 even at 1h"))
        rows.append((f"fig8/acc_dynamic/lifetime={lt_min}min",
                     float(np.mean(accs_d)), "paper: ~1 for >=4min"))
    return rows


def lemma_table():
    rows = []
    top = _top(2000)
    pa = dataclasses.replace(WAN, ttl=eccentricity_ttl(top, 0) + 1)
    eng = SimEngine(top, pa)
    met_b = _run(eng, 0, strategy="basic", dynamic=False)
    degs = top.degree()
    exact1 = int(degs.sum() - met_b.n_reached + 1)
    rows.append(("lemma1/m_fw_basic", met_b.m_fw, f"exact={exact1}"))
    met_1 = _run(eng, 0, strategy="st1", dynamic=False)
    rows.append(("lemma3/m_fw_st1", met_1.m_fw,
                 f"|E|={met_b.n_edges_pq} (w.h.p. equal)"))
    met_12 = _run(eng, 0, strategy="st1+2", dynamic=False)
    rows.append(("thm1/m_fw_st1+2", met_12.m_fw,
                 f"<=|E|={met_b.n_edges_pq}"))
    rows.append(("lemma2/lower_bound", met_b.n_reached - 1,
                 "|P_Q|-1 list transfers"))
    rows.append(("sec3.2/m_bw", met_b.m_bw, f"|P_Q|-1={met_b.n_reached - 1}"))
    rows.append(("sec3.2/b_bw_bytes", met_b.b_bw,
                 f"k*L*(|P_Q|-1)={WAN.k * 10 * (met_b.n_reached - 1)}"))
    rows.append(("sec3.2/m_rt", met_b.m_rt, f"<=2k={2 * WAN.k}"))
    return rows


ALL = {
    "fig2": fig2_cluster_scaleup,
    "fig3": fig3_scaleup_vs_baselines,
    "fig4": fig4_bandwidth,
    "fig5": fig5_latency,
    "fig6": fig6_comm_cost,
    "fig7": fig7_statistics,
    "fig8": fig8_dynamicity,
    "lemmas": lemma_table,
}
