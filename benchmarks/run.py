"""Benchmark runner: one suite per paper figure/table + TPU comm models.

  PYTHONPATH=src python -m benchmarks.run [suite ...]

Prints ``name,value,derived`` CSV rows (the contract used by
EXPERIMENTS.md §Repro) and a per-suite wall time.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks.multi_query import ALL as MULTI
    from benchmarks.paper_figures import ALL as FIGS
    from benchmarks.tpu_comm import ALL as COMM
    suites = dict(FIGS)
    suites.update(COMM)
    suites.update(MULTI)
    want = sys.argv[1:] or list(suites)
    print("name,value,derived")
    for name in want:
        if name not in suites:
            print(f"# unknown suite {name}; have {sorted(suites)}",
                  file=sys.stderr)
            continue
        t0 = time.time()
        rows = suites[name]()
        for rname, val, derived in rows:
            sval = f"{val:.6g}" if isinstance(val, float) else str(val)
            print(f'{rname},{sval},"{derived}"')
        print(f"# suite {name}: {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
