"""Benchmark regression gate — fail CI when an acceptance row regresses.

Compares a freshly produced ``BENCH_multi_query.json`` against the
committed baseline and fails when any acceptance-row speedup

  * ``speedup``      — batched engine vs scalar-reference loop (PR 1),
  * ``plan_cache``   — warm NetworkPlan vs cold rebuild (ISSUE 2),
  * ``jax_backend``  — jitted JAX engine vs scalar reference (ISSUE 3),
  * ``jax_churn``    — jitted churn sweep vs scalar reference, per
    lifetime regime (ISSUE 4)

drops by more than ``--tolerance`` (default 20%) below the baseline's,
or violates its absolute acceptance floor:

  * ``speedup``     >= 10x   (one batched call vs the scalar loop)
  * ``plan_cache``  >  1x    (warm plan must beat cold)
  * ``jax_backend`` >= 3x    vs the scalar reference, with the
    entry-wise ``parity`` bit set (bit-exactness asserted at scale)
  * ``jax_churn``   >= 3x    vs the scalar reference in EVERY lifetime
    regime, parity bit required — churn-path perf regressions (or a
    silent return to the numpy fallback) fail the workflow; the
    relative band is widened to 40% for these rows (see
    ``_SUITE_TOLERANCE``) because their ratio noise on small CI
    runners exceeds the default 20%

The ``precision`` rows (ISSUE 10) gate the reduced-precision jax
sweeps: every row must carry the tolerance-contract ``tol_ok`` bit
(top-k owner recall + positional score rtol vs the engine's own f64
rerun — recall == 1.0 required exactly when the f64 scores are
separated at the cast's resolution).  On accelerator platforms the f32
row additionally gates on ``speedup_vs_f64`` >= 1x (40% band — wall
ratio); on CPU, where the f64 sweep is already memory-bound and
vectorized, and for bf16 rows everywhere, the ratio is recorded but
only the tolerance bits gate.  The ``precision_scale`` row (1M-peer
int32-indexed plan answering an f32 query on one host) is
tolerance-bits-only: its ``run_s`` is recorded, the contract is that
the row EXISTS and validates.

The ``topology_sweep`` rows (ISSUE 5) are PARITY-ONLY: every
registered topology family must be present with its in-suite
numpy-vs-jax entry-wise equality bit set (asserted on a 100k-peer
hierarchical overlay in the full sweep) — their ``vs_numpy`` ratio is
recorded but not gated, because the two backends land near parity on
CI CPUs and the ratio is pure noise there.

The ``serving`` rows (ISSUE 6, produced by ``benchmarks/loadgen.py``
into ``BENCH_serving.json``) gate the always-on QueryServer: their
value field is absolute ``throughput_qps`` rather than a speedup ratio
(floor 25 qps — CI-runner safe; the relative band is widened to 50%
because wall-clock throughput on shared 2-core runners swings far more
than compute ratios; ``backend: "jax"`` rows are parity+batched-only,
their wall clock being XLA-compile-dominated on CI), and every row
must carry BOTH the ``parity`` bit
(served results bit-exact vs one-at-a-time ``Engine.run()``) and the
``batched`` bit (dynamic batching actually fused > 1 request) — a
server that serves correct bits without ever coalescing fails the
gate, as does one that batches fast but wrong.

The ``overlay_dynamics`` / ``overlay_churn`` / ``overlay_replication``
rows (ISSUE 9, produced by ``benchmarks/overlay_dynamics.py`` into
``BENCH_overlay_dynamics.json``) gate the live-overlay path: a single
join/leave must sync a warmed 100k-peer plan >= 5x faster than a
from-scratch rebuild (20k in ``--fast``; 40% band — wall-clock ratio),
batched churn must still beat the rebuild (>= 1x), and every row —
including the parity-only replication recall/traffic rows — must carry
the bit-exactness parity bit (synced plan == rebuilt plan == scalar
reference across backends and RNG modes; see docs/OVERLAY.md).

Rows are matched on (suite + identity params); a baseline acceptance
row with no matching current row is itself a failure, so suites cannot
silently disappear.

  PYTHONPATH=src python -m benchmarks.regression_gate \
      --current BENCH_multi_query.json \
      --baseline benchmarks/baselines/BENCH_multi_query.fast.json

  PYTHONPATH=src python -m benchmarks.regression_gate \
      --current BENCH_serving.json \
      --baseline benchmarks/baselines/BENCH_serving.fast.json
"""
from __future__ import annotations

import argparse
import json
import sys

# identity params per acceptance suite (everything else is measurement)
_KEYS = {
    "speedup": ("n_peers", "n_queries", "n_trials"),
    "plan_cache": ("n_peers", "n_queries", "n_trials", "n_policies"),
    "jax_backend": ("n_peers", "k", "n_queries", "n_trials"),
    "jax_churn": ("n_peers", "k", "lifetime_s", "n_queries", "n_trials"),
    "topology_sweep": ("topology", "latency_model", "n_peers", "k",
                       "n_queries", "n_trials"),
    "precision": ("n_peers", "precision", "k", "n_queries", "n_trials"),
    "precision_scale": ("n_peers", "index_dtype", "precision"),
    "serving": ("backend", "concurrency", "n_requests"),
    "overlay_dynamics": ("event", "n_peers"),
    "overlay_churn": ("events_per_sync", "n_peers"),
    "overlay_replication": ("replication_factor", "placement", "n_peers"),
}
_FLOORS = {"speedup": 10.0, "plan_cache": 1.0, "jax_backend": 3.0,
           "jax_churn": 3.0, "precision": 1.0, "serving": 25.0,
           "overlay_dynamics": 5.0, "overlay_churn": 1.0}
_PARITY_SUITES = ("jax_backend", "jax_churn", "precision",
                  "precision_scale", "topology_sweep", "serving",
                  "overlay_dynamics", "overlay_churn",
                  "overlay_replication")
# gated value field per suite (default: the "speedup" ratio); serving
# rows gate an absolute throughput instead
_VALUE_FIELD = {"serving": "throughput_qps",
                "precision": "speedup_vs_f64"}
# required boolean bits beyond parity
_REQUIRED_BITS = {"serving": ("batched",),
                  "precision": ("tol_ok",),
                  "precision_scale": ("tol_ok",)}
# suites gated on presence + parity only (no speedup floor/band): the
# numpy-vs-jax ratio on CI CPUs is noise, the bit-exactness is the
# contract; the replication rows measure recall/traffic trade-offs,
# not a speedup, so only their cross-backend parity gates
_PARITY_ONLY = ("topology_sweep", "overlay_replication",
                "precision_scale")
# per-suite minimum tolerance: the churn rows divide two wall-clock
# measurements whose run-to-run swing on 2-core CI runners exceeds the
# default 20% band (observed 6.1x-8.5x for the same build), so the
# relative check uses a wider band there; the absolute 3x floor and the
# parity bit still gate every run.  Same story for the overlay sync-vs-
# rebuild ratios (two wall clocks; the 5x / 1x absolute floors are the
# real contract)
_SUITE_TOLERANCE = {"jax_churn": 0.40, "precision": 0.40,
                    "serving": 0.50, "overlay_dynamics": 0.40,
                    "overlay_churn": 0.40}


def _parity_only(suite: str, row: dict) -> bool:
    """Rows gated on their boolean bits only (no value floor/band)."""
    if suite in _PARITY_ONLY:
        return True
    if suite == "serving" and row.get("backend") == "jax":
        return True
    # precision rows: the >= 1x speedup-vs-f64 floor is an accelerator
    # contract — on CPU the f64 sweep is already memory-bound and
    # vectorized so the ratio is ~1x noise; there (and for bf16, whose
    # value is numerical-robustness coverage, not speed) only the
    # tolerance-contract bits gate
    return suite == "precision" and (row.get("precision") == "bf16"
                                     or row.get("platform") == "cpu")


def _rows(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    out = {}
    for r in data["results"]:
        suite = r.get("suite")
        if suite in _KEYS:
            key = (suite,) + tuple(r[k] for k in _KEYS[suite])
            out[key] = r
    return out


def check(current: str, baseline: str, tolerance: float) -> list:
    cur, base = _rows(current), _rows(baseline)
    failures = []
    for key, brow in sorted(base.items()):
        suite = key[0]
        crow = cur.get(key)
        tag = "/".join(str(k) for k in key)
        if crow is None:
            failures.append(f"{tag}: acceptance row missing from "
                            f"{current}")
            continue
        if _parity_only(suite, crow):
            ok = crow.get("parity", False)
            print(f"{tag}: parity={ok} {'ok' if ok else 'FAIL'}")
            if not ok:
                failures.append(f"{tag}: backend parity bit not set")
            for bit in _REQUIRED_BITS.get(suite, ()):
                if not crow.get(bit, False):
                    failures.append(
                        f"{tag}: required bit {bit!r} not set")
            continue
        field = _VALUE_FIELD.get(suite, "speedup")
        unit = "" if field == "speedup" else " " + field.split("_")[-1]
        got, ref = crow[field], brow[field]
        tol = max(tolerance, _SUITE_TOLERANCE.get(suite, 0.0))
        floor = max(_FLOORS[suite], (1.0 - tol) * ref)
        status = "ok" if got >= floor else "FAIL"
        sym = "x" if field == "speedup" else unit
        print(f"{tag}: {got:.2f}{sym} (baseline {ref:.2f}{sym}, "
              f"floor {floor:.2f}{sym}) {status}")
        if got < floor:
            failures.append(
                f"{tag}: {field} {got:.2f} is below floor {floor:.2f} "
                f"(baseline {ref:.2f}, tolerance {tol:.0%})")
        if suite in _PARITY_SUITES and not crow.get("parity", False):
            failures.append(f"{tag}: parity bit not set")
        for bit in _REQUIRED_BITS.get(suite, ()):
            if not crow.get(bit, False):
                failures.append(f"{tag}: required bit {bit!r} not set")
    if not base:
        failures.append(f"no acceptance rows found in {baseline}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", default="BENCH_multi_query.json")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/"
                            "BENCH_multi_query.fast.json")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed fractional regression vs baseline")
    args = ap.parse_args()
    failures = check(args.current, args.baseline, args.tolerance)
    if failures:
        print("\nbenchmark regression gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        sys.exit(1)
    print("\nbenchmark regression gate passed")


if __name__ == "__main__":
    main()
