"""TPU-side FD benchmarks: collective-byte model for the vocab top-k
(the serving hot path) and measured wall-clock of the three algorithms
on host devices, plus compressed-gradient DCN byte model.

These mirror the paper's §5.3 communication tables onto the TPU mesh:
CN = all-gather full logits; CN* = gather k-lists to one peer; FD =
tree merge of k-lists.
"""
from __future__ import annotations

import time


from repro.configs.base import get_config, list_archs
from repro.core.fd import comm_bytes
from repro.optim.compress import compression_ratio, inflate_k


def vocab_topk_bytes():
    """Per-decode-step bytes over the model axis for every arch @ TP=16."""
    rows = []
    tp = 16
    k = 20
    for arch in list_archs():
        cfg = get_config(arch)
        v = cfg.padded_vocab()
        n_local = v // tp
        cn = comm_bytes("cn", tp, n_local, k, elem_bytes=4)
        cns = comm_bytes("cn_star", tp, n_local, k)
        fd_h = comm_bytes("fd", tp, n_local, k, schedule="halving")
        fd_d = comm_bytes("fd", tp, n_local, k, schedule="doubling")
        rows.append((f"vocab_topk/{arch}/cn_bytes", cn, f"V={v} TP={tp}"))
        rows.append((f"vocab_topk/{arch}/cn_star_bytes", cns, ""))
        rows.append((f"vocab_topk/{arch}/fd_halving_bytes", fd_h,
                     f"reduction vs CN: {cn / fd_h:.0f}x"))
        rows.append((f"vocab_topk/{arch}/fd_doubling_bytes", fd_d, ""))
    return rows


def fd_wallclock():
    """Measured serve-sampling step on the host mesh (1 device: the
    algorithmic overhead only; collective deltas appear in the dry-run)."""
    import jax
    import jax.numpy as jnp
    from repro.core.fd import fd_topk
    from repro.launch.mesh import make_host_mesh
    rows = []
    mesh = make_host_mesh(model=1)
    n_dev = len(jax.devices())
    scores = jax.random.normal(jax.random.PRNGKey(0), (8, 152064))
    for alg in ("fd", "cn", "cn_star"):
        if n_dev == 1:
            fn = jax.jit(lambda s: jax.lax.top_k(s, 20))
        else:
            fn = jax.jit(lambda s: fd_topk(s, 20, mesh, "model",
                                           algorithm=alg))
        fn(scores)[0].block_until_ready()
        t0 = time.perf_counter()
        for _ in range(20):
            out = fn(scores)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / 20 * 1e6
        rows.append((f"fd_wallclock/{alg}", us, "us/call host-mesh"))
    return rows


def grad_compression_model():
    """DCN bytes per step for cross-pod gradient sync: dense vs FD top-k
    (k = 0.1% of entries, Lemma-4 inflated for 5% pod drop)."""
    rows = []
    n_pods = 2
    for arch in ("qwen2-0.5b", "phi3-medium-14b", "qwen2-vl-72b"):
        cfg = get_config(arch)
        n = cfg.param_count()
        k = inflate_k(max(1, int(1e-3 * n)), 0.05)
        dense = 4 * n * 2 * (n_pods - 1) / n_pods
        sparse = 8 * k * (n_pods - 1)
        rows.append((f"grad_compress/{arch}/dense_MB", dense / 1e6,
                     f"N={n / 1e9:.2f}B params"))
        rows.append((f"grad_compress/{arch}/fd_topk_MB", sparse / 1e6,
                     f"k={k} (Lemma4 P=0.05)"))
        rows.append((f"grad_compress/{arch}/ratio",
                     compression_ratio(n, k, n_pods), "dense/sparse"))
    return rows


ALL = {
    "vocab_topk_bytes": vocab_topk_bytes,
    "fd_wallclock": fd_wallclock,
    "grad_compression": grad_compression_model,
}
