"""Batched multi-query benchmark suite (engine entrypoint).

Sweeps ``SimEngine`` over (n_peers, k, churn, policy) and the TPU-side
collectives over (schedule, k), and measures two headline numbers:

  * ``speedup`` — one batched engine call vs a Python loop of scalar
    ``run_query_reference`` calls (the PR-1 acceptance measurement);
  * ``plan_cache`` — a warm engine (compiled ``NetworkPlan`` reused
    across ``run`` calls) vs a cold engine built per call (the ISSUE-2
    acceptance measurement; CI asserts warm beats cold).

  PYTHONPATH=src python -m benchmarks.multi_query [--fast] [--out PATH]

writes ``BENCH_multi_query.json``:

  {
    "meta":    {"created_unix": float, "fast": bool, "jax": str,
                "numpy": str},
    "results": [
      {"suite": "sim",   "n_peers": int, "k": int, "policy": str,
       "lifetime_s": float|null, "n_queries": int, "n_trials": int,
       "wall_s": float, "queries_per_s": float,
       "mean_total_bytes": float, "mean_total_messages": float,
       "mean_response_s": float, "mean_accuracy": float},
      {"suite": "speedup", "n_peers": int, "n_queries": int,
       "n_trials": int, "batch_s": float, "loop_s": float,
       "speedup": float},
      {"suite": "plan_cache", "n_peers": int, "n_queries": int,
       "n_trials": int, "n_policies": int, "warm_s": float,
       "cold_s": float, "speedup": float},
      {"suite": "jax_backend", "n_peers": int, "k": int,
       "n_queries": int, "n_trials": int, "jax_s": float,
       "numpy_s": float, "reference_s": float, "speedup": float,
       "vs_batch_numpy": float, "parity": bool},
      {"suite": "jax_churn", "n_peers": int, "k": int,
       "lifetime_s": float, "n_queries": int, "n_trials": int,
       "jax_s": float, "numpy_s": float, "reference_s": float,
       "speedup": float, "vs_batch_numpy": float, "parity": bool},
      {"suite": "precision", "n_peers": int, "k": int, "precision": str,
       "n_queries": int, "n_trials": int, "platform": str,
       "jax64_s": float, "jax_s": float, "speedup_vs_f64": float,
       "recall": float, "max_rtol": float, "separated": bool,
       "tol_ok": bool, "parity": bool},
      {"suite": "precision_scale", "n_peers": int, "k": int,
       "index_dtype": str, "precision": str, "build_s": float,
       "run_s": float, "recall": float, "max_rtol": float,
       "tol_ok": bool, "parity": bool},
      {"suite": "topology_sweep", "topology": str, "latency_model": str,
       "n_peers": int, "k": int, "n_queries": int, "n_trials": int,
       "numpy_s": float, "jax_s": float, "vs_numpy": float,
       "mean_m_bw": float, "mean_response_s": float,
       "mean_total_bytes": float, "parity": bool},
      {"suite": "tpu", "schedule": str, "k": int, "n_dev": int,
       "n_local": int, "model_bytes": int, "measured_bytes": int,
       "wall_us_per_call": float}
    ]
  }
"""
from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

from repro.engine import NetworkPlan, QuerySpec, SimEngine, get_policy
from repro.p2psim import (SimParams, available_topologies,
                          barabasi_albert, build_topology,
                          run_query_reference)

SIM_POLICIES = ("fd-dynamic", "cn", "cn-star")
_PARITY_FIELDS = ("n_reached", "n_edges_pq", "m_fw", "m_bw", "m_rt",
                  "b_fw", "b_bw", "b_rt", "response_time_s", "accuracy")


def sim_sweep(fast: bool = False):
    results = []
    sizes = (128, 256) if fast else (128, 256, 512)
    ks = (20,) if fast else (10, 20)
    lifetimes = (None,) if fast else (None, 60.0)
    nq, nt = (16, 2) if fast else (32, 4)
    for n_peers in sizes:
        top = barabasi_albert(n_peers, m=2, seed=7)
        origins = tuple(int(o) for o in np.random.default_rng(0)
                        .integers(0, n_peers, nq))
        engine = SimEngine(top)       # NetworkPlan shared by the sweep
        for k in ks:
            spec = QuerySpec(origins=origins, n_trials=nt, k=k, seed=0)
            for lt in lifetimes:
                for name in SIM_POLICIES:
                    pol = get_policy(name)
                    if lt is not None:
                        pol = pol.variant(lifetime_mean_s=lt)
                    engine.run(spec, pol)   # warm the plan so every row
                    t0 = time.perf_counter()  # times execution, not build
                    bm = engine.run(spec, pol).metrics
                    wall = time.perf_counter() - t0
                    results.append({
                        "suite": "sim", "n_peers": n_peers, "k": k,
                        "policy": name, "lifetime_s": lt,
                        "n_queries": nq, "n_trials": nt, "wall_s": wall,
                        "queries_per_s": nq * nt / wall,
                        "mean_total_bytes": float(bm.total_bytes.mean()),
                        "mean_total_messages": float(
                            bm.total_messages.mean()),
                        "mean_response_s": float(
                            bm.response_time_s.mean()),
                        "mean_accuracy": float(bm.accuracy.mean()),
                    })
    return results


def speedup_bench(fast: bool = False):
    """Batched engine call vs scalar-reference loop, best-of-N."""
    n_peers, nq, nt = 256, 64, 4
    top = barabasi_albert(n_peers, m=2, seed=7)
    p = SimParams(seed=5)
    origins = np.random.default_rng(0).integers(0, n_peers, nq)
    engine = SimEngine(top, p)
    spec = QuerySpec(origins=tuple(int(o) for o in origins), n_trials=nt)
    engine.run(spec)                                  # warm numpy caches
    reps_b, reps_l = (3, 1) if fast else (5, 2)
    batch_s = min(_timed(lambda: SimEngine(top, p).run(spec))
                  for _ in range(reps_b))             # cold, like the loop
    def loop():
        for q in range(nq):
            for t in range(nt):
                run_query_reference(
                    top, int(origins[q]),
                    dataclasses.replace(p, seed=p.seed + q * nt + t))
    loop_s = min(_timed(loop) for _ in range(reps_l))
    return [{"suite": "speedup", "n_peers": n_peers, "n_queries": nq,
             "n_trials": nt, "batch_s": batch_s, "loop_s": loop_s,
             "speedup": loop_s / batch_s}]


def plan_cache_bench(fast: bool = False):
    """Warm NetworkPlan reuse vs cold per-call preprocessing.

    The warm engine runs the same workload (three policies over the same
    origin set) on one prepared engine; the cold side builds a fresh
    ``SimEngine`` — CSR, directed edges, BFS trees, forward masks — for
    every call, which is exactly what the legacy ``run_queries`` shim
    does.  Best-of-N both sides.
    """
    n_peers, nq, nt = 256, 64, 1
    top = barabasi_albert(n_peers, m=2, seed=7)
    p = SimParams(seed=3)
    spec = QuerySpec(origins=tuple(int(o) for o in np.random.default_rng(1)
                                   .integers(0, n_peers, nq)), n_trials=nt)
    engine = SimEngine(top, p)
    def warm():
        for name in SIM_POLICIES:
            engine.run(spec, name)
    def cold():
        for name in SIM_POLICIES:
            SimEngine(top, p).run(spec, name)
    warm()                                            # populate the plan
    reps = 5                    # best-of-5 even in --fast: the CI gate
    warm_s = min(_timed(warm) for _ in range(reps))   # asserts warm < cold
    cold_s = min(_timed(cold) for _ in range(reps))
    return [{"suite": "plan_cache", "n_peers": n_peers, "n_queries": nq,
             "n_trials": nt, "n_policies": len(SIM_POLICIES),
             "warm_s": warm_s, "cold_s": cold_s,
             "speedup": cold_s / warm_s}]


def jax_backend_bench(fast: bool = False):
    """SimEngine(backend="jax") on a Gnutella-shaped BA overlay (§5.1).

    The acceptance measurement of the jitted backend: the same
    independent-streams workload is run through

      * the jitted JAX engine (``speedup`` numerator's subject),
      * the scalar ``run_query_reference`` loop — the paper-fidelity
        numpy simulator every engine is bit-exact against
        (``reference_s``; the suite's ``speedup`` convention, like the
        PR-1 batched-vs-scalar acceptance row), and
      * the vectorized numpy batch backend (``vs_batch_numpy``) — on a
        2-core CPU the f64 merge sweeps of both backends are memory
        bound and land near parity; the jitted path pulls ahead on
        accelerators where the Pallas merge kernel lowers natively.

    Entry-wise bit-parity between the jax engine and the scalar
    reference is ASSERTED here at full scale (``parity``), so the
    speedup rows can never drift away from the exactness contract.
    """
    n_peers = 20_000 if fast else 100_000
    nq, nt = 2, 2
    top = barabasi_albert(n_peers, m=2, seed=7)
    p = SimParams(seed=5)
    spec = QuerySpec(origins=(0, 1), n_trials=nt, seed=5,
                     rng="independent")
    eng_np = SimEngine(top, p)
    eng_jx = SimEngine(top, p, backend="jax")
    eng_np.run(spec)                      # warm plans + jit caches
    eng_jx.run(spec)
    reps = 2 if fast else 3
    numpy_s = min(_timed(lambda: eng_np.run(spec)) for _ in range(reps))
    jax_s = min(_timed(lambda: eng_jx.run(spec)) for _ in range(reps))
    res = eng_jx.run(spec)
    t0 = time.perf_counter()
    parity = True
    for q in range(nq):
        for t in range(nt):
            met, _ = run_query_reference(
                top, q, dataclasses.replace(p, seed=p.seed + q * nt + t))
            parity = parity and res.query_metrics(q, t) == met
    reference_s = time.perf_counter() - t0
    assert parity, "jax backend diverged from run_query_reference"
    return [{"suite": "jax_backend", "n_peers": n_peers, "k": p.k,
             "n_queries": nq, "n_trials": nt, "jax_s": jax_s,
             "numpy_s": numpy_s, "reference_s": reference_s,
             "speedup": reference_s / jax_s,
             "vs_batch_numpy": numpy_s / jax_s, "parity": parity}]


def jax_churn_bench(fast: bool = False):
    """SimEngine(backend="jax") under churn (§4/§5.4) at overlay scale.

    The acceptance measurement of the churn-aware jitted sweep: the
    scenarios the paper cares most about — peers leaving mid-query,
    urgent forwarding, dead-parent rerouting — across several lifetime
    regimes (heavy churn where a meaningful fraction of peers dies
    before sending, and light churn where deaths are rare but the
    masked/reroute-augmented sweep still runs).  Per regime the same
    independent-streams workload runs through the jitted engine, the
    vectorized numpy backend, and a scalar ``run_query_reference``
    loop; entry-wise bit-parity with the reference is ASSERTED at full
    scale, as is the absence of any numpy fallback
    (``backend_used == "sim-jax"``).
    """
    n_peers = 20_000 if fast else 100_000
    nq, nt = 2, 2
    lifetimes = (60.0, 600.0)
    top = barabasi_albert(n_peers, m=2, seed=7)
    p = SimParams(seed=5)
    spec = QuerySpec(origins=(0, 1), n_trials=nt, seed=5,
                     rng="independent")
    eng_np = SimEngine(top, p)
    eng_jx = SimEngine(top, p, backend="jax")
    reps = 2 if fast else 3
    results = []
    for lt in lifetimes:
        pol = get_policy("fd-dynamic").variant(lifetime_mean_s=lt)
        eng_np.run(spec, pol)             # warm plans + jit caches
        eng_jx.run(spec, pol)
        numpy_s = min(_timed(lambda: eng_np.run(spec, pol))
                      for _ in range(reps))
        jax_s = min(_timed(lambda: eng_jx.run(spec, pol))
                    for _ in range(reps))
        res = eng_jx.run(spec, pol)
        assert res.backend_used == "sim-jax", "churn fell back to numpy"
        t0 = time.perf_counter()
        parity = True
        for q in range(nq):
            for t in range(nt):
                met, _ = run_query_reference(
                    top, q,
                    dataclasses.replace(p, seed=p.seed + q * nt + t),
                    lifetime_mean_s=lt)
                parity = parity and res.query_metrics(q, t) == met
        reference_s = time.perf_counter() - t0
        assert parity, ("jax churn backend diverged from "
                        f"run_query_reference (lifetime {lt})")
        results.append({
            "suite": "jax_churn", "n_peers": n_peers, "k": p.k,
            "lifetime_s": lt, "n_queries": nq, "n_trials": nt,
            "jax_s": jax_s, "numpy_s": numpy_s,
            "reference_s": reference_s,
            "speedup": reference_s / jax_s,
            "vs_batch_numpy": numpy_s / jax_s, "parity": parity})
    return results


def precision_bench(fast: bool = False):
    """Reduced-precision jax sweeps vs the f64 jax sweep (ISSUE 10).

    Per precision mode the same independent-streams workload runs
    through the reduced-precision engine twice: untimed WITH validation
    (recording the tolerance contract — top-k owner recall + positional
    score rtol vs the engine's own f64 rerun) and timed WITHOUT
    (``validate_precision=False``, so the timed path is the reduced
    sweep alone).  The tolerance ``ok`` bit is ASSERTED for every row —
    and recall == 1.0 outright whenever the f64 scores are separated at
    the cast's resolution (bf16 spacing near 1.0 is ~0.004, so U(0,1)
    top scores legitimately collapse into ties there; the contract
    exempts recall exactly then, see docs/BENCHMARKS.md PRECISION).

    ``speedup_vs_f64`` is the acceptance ratio on accelerator
    platforms (asserted >= 1.5 for f32 in the full sweep there); on CPU
    the f64 sweep is already memory-bound and vectorized, the ratio
    lands near 1x and only the tolerance bits gate (same convention as
    the serving suite's compile-dominated jax rows).
    """
    import jax
    n_peers = 20_000 if fast else 100_000
    nq, nt = 2, 2
    platform = jax.default_backend()
    top = barabasi_albert(n_peers, m=2, seed=7)
    p = SimParams(seed=5)
    spec = QuerySpec(origins=(0, 1), n_trials=nt, seed=5,
                     rng="independent")
    plan = NetworkPlan(top)              # shared: one BFS per origin
    eng64 = SimEngine(plan, p, backend="jax")
    eng64.run(spec)                      # warm plan + jit caches
    reps = 2 if fast else 3
    f64_s = min(_timed(lambda: eng64.run(spec)) for _ in range(reps))
    rows = []
    for prec in ("f32", "bf16"):
        eng = SimEngine(plan, p, backend="jax", precision=prec,
                        validate_precision=False)
        eng.run(spec)
        lo_s = min(_timed(lambda: eng.run(spec)) for _ in range(reps))
        veng = SimEngine(plan, p, backend="jax", precision=prec)
        tol = veng.run(spec).extras["tolerance"]
        assert tol["ok"], f"{prec} tolerance contract violated: {tol}"
        if tol["separated"]:
            assert tol["recall"] == 1.0, (prec, tol)
        row = {"suite": "precision", "n_peers": n_peers, "k": p.k,
               "precision": prec, "n_queries": nq, "n_trials": nt,
               "platform": platform, "jax64_s": f64_s, "jax_s": lo_s,
               "speedup_vs_f64": f64_s / lo_s, "recall": tol["recall"],
               "max_rtol": tol["max_rtol"],
               "separated": tol["separated"], "tol_ok": tol["ok"],
               "parity": tol["ok"]}
        if prec == "f32" and platform != "cpu" and not fast:
            assert row["speedup_vs_f64"] >= 1.5, (
                "accelerator acceptance: f32 sweep must be >= 1.5x "
                f"over f64, got {row['speedup_vs_f64']:.2f}x")
        rows.append(row)
    return rows


def precision_scale_bench(fast: bool = False):
    """1M-peer plan under int32 indices + f32 sweep (ISSUE 10 memory
    acceptance: the plan must build AND answer a query on one host).

    A star overlay (1M spokes sharing one literal neighbor array keeps
    the host-side build cheap) exercises the widest single level the
    sweep can see — (1, 1M) level arrays — with every index array
    int32 and every float array f32; the run is validated against the
    engine's own f64 rerun, so the tolerance bit gates here too.  Runs
    in BOTH the fast and full legs.
    """
    from repro.p2psim.graph import Topology
    n = 1_000_000
    hub = np.arange(1, n, dtype=np.int32)
    spoke = np.array([0], dtype=np.int32)   # shared by all 1M spokes
    top = Topology(n=n, neighbors=[hub] + [spoke] * (n - 1), kind="star")
    t0 = time.perf_counter()
    plan = NetworkPlan(top, index_dtype="int32")
    build_s = time.perf_counter() - t0
    assert plan.index_dtype == np.int32
    assert plan.edge_keys.dtype == np.int64     # n^2 > 2^31: stays wide
    eng = SimEngine(plan, SimParams(seed=3), backend="jax",
                    precision="f32")
    t0 = time.perf_counter()
    res = eng.run(QuerySpec(origins=(0,), seed=3))
    run_s = time.perf_counter() - t0
    tol = res.extras["tolerance"]
    assert tol["ok"], f"1M-peer f32 tolerance contract violated: {tol}"
    return [{"suite": "precision_scale", "n_peers": n, "k": 20,
             "index_dtype": "int32", "precision": "f32",
             "build_s": build_s, "run_s": run_s,
             "recall": tol["recall"], "max_rtol": tol["max_rtol"],
             "tol_ok": tol["ok"], "parity": tol["ok"]}]


def topology_sweep(fast: bool = False):
    """Every registered topology family through BOTH sim backends.

    The ISSUE-5 acceptance measurement: per family the same
    independent-streams workload runs through the numpy and the jitted
    JAX engine (one shared ``NetworkPlan``), under the per-edge BRITE
    latency model wherever the family carries coordinates (``"iid"``
    for flat BA, which has no embedding) — and entry-wise metric
    equality between the two backends is ASSERTED (``parity``), at
    100k-peer scale for the hierarchical family in the full sweep.  The
    recorded ``mean_m_bw`` / ``mean_response_s`` rows are the
    cross-family comparison the paper's §5 response-time results can be
    read against: topology shape (power-law vs. random vs. hierarchical
    vs. degree-homogeneous) and the distance-derived latencies both
    move the traffic and latency outcomes.

    The hierarchical family runs at ``n_hier`` (100k full, 20k fast);
    the flat families at ``n_flat``; Waxman at its O(n^2)-build scale.
    """
    n_flat = 2_000 if fast else 20_000
    n_hier = 20_000 if fast else 100_000
    nq, nt = 2, 2
    reps = 2 if fast else 3
    results = []
    for name in available_topologies():
        n_peers = {"hierarchical": n_hier,
                   "waxman": min(n_flat, 2_000)}.get(name, n_flat)
        top = build_topology(name, n_peers, seed=7)
        lm = "edge" if top.coords is not None else "iid"
        p = SimParams(seed=5, latency_model=lm)
        spec = QuerySpec(origins=(0, 1), n_trials=nt, seed=5,
                         rng="independent")
        plan = NetworkPlan(top)               # shared: one BFS per origin
        eng_np = SimEngine(plan, p)
        eng_jx = SimEngine(plan, p, backend="jax")
        eng_np.run(spec)                      # warm plan + jit caches
        eng_jx.run(spec)
        numpy_s = min(_timed(lambda: eng_np.run(spec))
                      for _ in range(reps))
        jax_s = min(_timed(lambda: eng_jx.run(spec)) for _ in range(reps))
        rn = eng_np.run(spec)
        rj = eng_jx.run(spec)
        assert rj.backend_used == "sim-jax"
        parity = all(
            np.array_equal(getattr(rn.metrics, f), getattr(rj.metrics, f))
            for f in _PARITY_FIELDS)
        assert parity, (f"jax backend diverged from numpy on topology "
                        f"{name!r} ({lm} latency, n={n_peers})")
        results.append({
            "suite": "topology_sweep", "topology": name,
            "latency_model": lm, "n_peers": n_peers, "k": p.k,
            "n_queries": nq, "n_trials": nt,
            "numpy_s": numpy_s, "jax_s": jax_s,
            "vs_numpy": numpy_s / jax_s,
            "mean_m_bw": float(rn.metrics.m_bw.mean()),
            "mean_response_s": float(rn.metrics.response_time_s.mean()),
            "mean_total_bytes": float(rn.metrics.total_bytes.mean()),
            "parity": parity})
    return results


def tpu_sweep(fast: bool = False):
    import jax
    from repro.core.fd import comm_bytes, fd_topk
    from repro.core.topology import measure_comm_bytes
    from repro.launch.mesh import make_host_mesh
    results = []
    mesh = make_host_mesh(model=len(jax.devices()))
    n_dev_real = dict(mesh.shape)["model"]
    n_model = 8                         # byte models at the deploy scale
    n_local = 4096
    ks = (20,) if fast else (8, 20)
    for schedule in ("halving", "doubling", "ring"):
        for k in ks:
            fn = jax.jit(lambda s, k=k, schedule=schedule: fd_topk(
                s, k, mesh, "model", schedule=schedule,
                batch_axes=("data",)))
            scores = jax.random.normal(jax.random.PRNGKey(0),
                                       (8, n_dev_real * n_local))
            fn(scores)[0].block_until_ready()
            t0 = time.perf_counter()
            for _ in range(10):
                out = fn(scores)
            jax.block_until_ready(out)
            us = (time.perf_counter() - t0) / 10 * 1e6
            results.append({
                "suite": "tpu", "schedule": schedule, "k": k,
                "n_dev": n_model, "n_local": n_local,
                "model_bytes": comm_bytes("fd", n_model, n_local, k,
                                          schedule=schedule),
                "measured_bytes": measure_comm_bytes(
                    "fd", n_model, n_local, k, schedule=schedule),
                "wall_us_per_call": us,
            })
    return results


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def collect(fast: bool = False) -> dict:
    import jax
    return {
        "meta": {"created_unix": time.time(), "fast": fast,
                 "jax": jax.__version__, "numpy": np.__version__},
        "results": (sim_sweep(fast) + speedup_bench(fast)
                    + plan_cache_bench(fast) + jax_backend_bench(fast)
                    + jax_churn_bench(fast) + precision_bench(fast)
                    + precision_scale_bench(fast) + topology_sweep(fast)
                    + tpu_sweep(fast)),
    }


def suite_rows():
    """benchmarks.run contract: (name, value, derived) rows (fast mode)."""
    data = collect(fast=True)
    rows = []
    for r in data["results"]:
        if r["suite"] == "sim":
            tag = (f"multi_query/sim/{r['policy']}/n={r['n_peers']}"
                   f"/k={r['k']}")
            rows.append((f"{tag}/qps", r["queries_per_s"],
                         f"{r['n_queries']}x{r['n_trials']} batch"))
            rows.append((f"{tag}/bytes", r["mean_total_bytes"],
                         "mean per query"))
        elif r["suite"] == "speedup":
            rows.append(("multi_query/speedup_vs_loop", r["speedup"],
                         "acceptance: >= 10x"))
        elif r["suite"] == "plan_cache":
            rows.append(("multi_query/plan_cache_speedup", r["speedup"],
                         "warm NetworkPlan vs cold; acceptance: > 1x"))
        elif r["suite"] == "jax_backend":
            rows.append((f"multi_query/jax_backend/n={r['n_peers']}"
                         "/speedup", r["speedup"],
                         "jitted engine vs scalar reference; "
                         "acceptance: >= 3x"))
            rows.append((f"multi_query/jax_backend/n={r['n_peers']}"
                         "/vs_batch_numpy", r["vs_batch_numpy"],
                         "jitted engine vs vectorized numpy backend"))
        elif r["suite"] == "jax_churn":
            rows.append((f"multi_query/jax_churn/n={r['n_peers']}"
                         f"/lt={r['lifetime_s']:g}/speedup", r["speedup"],
                         "jitted churn sweep vs scalar reference; "
                         "acceptance: >= 3x"))
        elif r["suite"] == "precision":
            tag = (f"multi_query/precision/{r['precision']}"
                   f"/n={r['n_peers']}")
            rows.append((f"{tag}/vs_f64", r["speedup_vs_f64"],
                         f"tol_ok={r['tol_ok']} recall={r['recall']:.3f}"
                         " (acceptance: tolerance contract)"))
        elif r["suite"] == "precision_scale":
            rows.append((f"multi_query/precision_scale/n={r['n_peers']}"
                         "/run_s", r["run_s"],
                         f"int32 plan, f32 sweep; tol_ok={r['tol_ok']}"))
        elif r["suite"] == "topology_sweep":
            tag = (f"multi_query/topology_sweep/{r['topology']}"
                   f"/n={r['n_peers']}")
            rows.append((f"{tag}/m_bw", r["mean_m_bw"],
                         f"{r['latency_model']} latency; parity="
                         f"{r['parity']} (acceptance: parity)"))
            rows.append((f"{tag}/response_s", r["mean_response_s"],
                         "mean per query"))
        else:
            rows.append((f"multi_query/tpu/{r['schedule']}/k={r['k']}"
                         "/bytes", r["model_bytes"],
                         f"measured={r['measured_bytes']}"))
    return rows


ALL = {"multi_query": suite_rows}


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: smaller sweeps, fewer reps")
    ap.add_argument("--out", default="BENCH_multi_query.json")
    args = ap.parse_args()
    data = collect(fast=args.fast)
    with open(args.out, "w") as f:
        json.dump(data, f, indent=2)
    sp = [r for r in data["results"] if r["suite"] == "speedup"][0]
    pc = [r for r in data["results"] if r["suite"] == "plan_cache"][0]
    jx = [r for r in data["results"] if r["suite"] == "jax_backend"][0]
    ch = [r for r in data["results"] if r["suite"] == "jax_churn"]
    churn = "; ".join(f"lt={r['lifetime_s']:g}s {r['speedup']:.1f}x"
                      for r in ch)
    ts = [r for r in data["results"] if r["suite"] == "topology_sweep"]
    topo = ", ".join(f"{r['topology']}({r['n_peers'] // 1000}k)"
                     for r in ts)
    pr = [r for r in data["results"] if r["suite"] == "precision"]
    prec = "; ".join(f"{r['precision']} {r['speedup_vs_f64']:.2f}x "
                     f"tol_ok={r['tol_ok']}" for r in pr)
    ps = [r for r in data["results"]
          if r["suite"] == "precision_scale"][0]
    print(f"wrote {args.out}: {len(data['results'])} results; "
          f"speedup_vs_loop={sp['speedup']:.1f}x; "
          f"plan_cache warm/cold={pc['speedup']:.2f}x; "
          f"jax_backend {jx['speedup']:.1f}x vs reference "
          f"({jx['vs_batch_numpy']:.2f}x vs batch numpy, "
          f"n={jx['n_peers']}); jax_churn {churn}; "
          f"precision {prec}; 1M-peer int32+f32 "
          f"run_s={ps['run_s']:.2f}; topology_sweep parity on {topo}")


if __name__ == "__main__":
    main()
