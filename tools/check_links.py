"""Markdown link checker for the docs CI job (stdlib only).

Scans the given markdown files / directories for inline links and
validates every RELATIVE target:

  * ``[text](path)``          — the file (or directory) must exist,
    resolved against the markdown file's own directory;
  * ``[text](path#anchor)`` / ``[text](#anchor)`` — the target file
    must additionally contain a heading whose GitHub slug matches
    ``anchor``.

External links (``http(s)://``, ``mailto:``) are counted but not
fetched — network checks are flaky in CI and the repo's externals are
badges and paper references.

  python tools/check_links.py README.md docs

Exits non-zero listing every broken link.
"""
from __future__ import annotations

import pathlib
import re
import sys

# inline links, skipping fenced code blocks and images' leading "!"
_LINK = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_FENCE = re.compile(r"^\s*(```|~~~)")
_HEADING = re.compile(r"^\s{0,3}#{1,6}\s+(.*?)\s*#*\s*$")


def _slug(heading: str) -> str:
    """GitHub-style anchor slug of a heading line."""
    text = re.sub(r"`([^`]*)`", r"\1", heading)        # strip code spans
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # inline links
    text = text.strip().lower()
    text = re.sub(r"[^\w\- ]", "", text, flags=re.UNICODE)
    return text.replace(" ", "-")


def _headings(path: pathlib.Path) -> set:
    slugs: dict = {}
    fenced = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if _FENCE.match(line):
            fenced = not fenced
            continue
        if fenced:
            continue
        m = _HEADING.match(line)
        if m:
            base = _slug(m.group(1))
            n = slugs.get(base, 0)
            slugs[base] = n + 1
            # duplicate headings get -1, -2, ... suffixes on GitHub
    out = set()
    for base, count in slugs.items():
        out.add(base)
        out.update(f"{base}-{i}" for i in range(1, count))
    return out


def _links(path: pathlib.Path):
    fenced = False
    for ln, line in enumerate(path.read_text(encoding="utf-8")
                              .splitlines(), 1):
        if _FENCE.match(line):
            fenced = not fenced
            continue
        if fenced:
            continue
        for m in _LINK.finditer(line):
            yield ln, m.group(1)


def check(paths) -> int:
    md_files = []
    for p in paths:
        p = pathlib.Path(p)
        md_files.extend(sorted(p.rglob("*.md")) if p.is_dir() else [p])
    errors = []
    n_links = n_external = 0
    for md in md_files:
        for ln, target in _links(md):
            n_links += 1
            if re.match(r"[a-z][a-z0-9+.-]*:", target):   # URL scheme
                n_external += 1
                continue
            raw, _, anchor = target.partition("#")
            dest = (md.parent / raw).resolve() if raw else md.resolve()
            if not dest.exists():
                errors.append(f"{md}:{ln}: broken link -> {target}")
                continue
            if anchor:
                if dest.is_dir() or dest.suffix.lower() != ".md":
                    errors.append(f"{md}:{ln}: anchor on non-markdown "
                                  f"target -> {target}")
                elif _slug(anchor) not in _headings(dest):
                    errors.append(f"{md}:{ln}: missing anchor -> "
                                  f"{target}")
    print(f"checked {n_links} links in {len(md_files)} files "
          f"({n_external} external, skipped)")
    for e in errors:
        print(e, file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(check(sys.argv[1:] or ["README.md", "docs"]))
