"""The paper's experiment, interactively: execute a Top-k query over a
BRITE-like unstructured overlay and compare FD / CN / CN* plus the
traffic-reduction strategies and churn handling.

Run:  PYTHONPATH=src python examples/p2p_query.py [--peers 2000] [--k 20]
"""
import argparse

from repro.p2psim import SimParams, barabasi_albert, run_query, waxman
from repro.p2psim.graph import eccentricity_ttl
from repro.p2psim.simulate import run_statistics_heuristic


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--peers", type=int, default=2000)
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--topology", choices=("ba", "waxman"), default="ba")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    gen = barabasi_albert if args.topology == "ba" else waxman
    top = gen(args.peers, seed=args.seed)
    pa = SimParams(k=args.k, seed=args.seed)
    print(f"overlay: {args.topology}, {args.peers} peers, "
          f"avg degree {top.avg_degree():.2f}, "
          f"TTL*={eccentricity_ttl(top, 0)}")

    print("\n-- algorithms (paper §5.2/5.3) --")
    print(f"{'algo':10s} {'messages':>10s} {'bytes':>12s} "
          f"{'resp (s)':>9s} {'accuracy':>8s}")
    for alg in ("fd", "cn_star", "cn"):
        met, _ = run_query(top, 0, pa, algorithm=alg)
        print(f"{alg:10s} {met.total_messages:>10,} {met.total_bytes:>12,} "
              f"{met.response_time_s:>9.1f} {met.accuracy:>8.2f}")

    print("\n-- forward strategies (paper §3.3) --")
    for strat in ("basic", "st1", "st1+2"):
        met, _ = run_query(top, 0, pa, strategy=strat, dynamic=False)
        print(f"{strat:10s} m_fw={met.m_fw:>8,}  total "
              f"bytes={met.total_bytes:>10,}")

    print("\n-- statistics heuristic (paper Fig 7) --")
    for z in (0.4, 0.8, 1.0):
        _, _, red, acc = run_statistics_heuristic(top, 0, pa, z=z)
        print(f"z={z:.1f}: comm -{red:.0%}, accuracy {acc:.0%}")

    print("\n-- churn (paper Fig 8) --")
    for lt in (1, 4, 30):
        mb, _ = run_query(top, 0, pa, dynamic=False, lifetime_mean_s=lt * 60)
        md, _ = run_query(top, 0, pa, dynamic=True, lifetime_mean_s=lt * 60)
        print(f"lifetime {lt:>3}min: FD-Basic acc={mb.accuracy:.2f}  "
              f"FD-Dynamic acc={md.accuracy:.2f}")


if __name__ == "__main__":
    main()
