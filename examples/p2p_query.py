"""The paper's experiment, interactively: execute Top-k queries over a
BRITE-like unstructured overlay and compare the whole policy registry —
FD / CN / CN*, the traffic-reduction strategies, the statistics
heuristic, and churn handling — through the unified engine API.

One ``SimEngine`` serves every comparison: the compiled ``NetworkPlan``
(CSR, BFS trees, forward masks, auto-TTL) is built once and reused.

Run:  PYTHONPATH=src python examples/p2p_query.py [--peers 2000] [--k 20]
"""
import argparse

from repro.engine import QuerySpec, SimEngine, get_policy
from repro.p2psim import SimParams, barabasi_albert, waxman


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--peers", type=int, default=2000)
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--topology", choices=("ba", "waxman"), default="ba")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    gen = barabasi_albert if args.topology == "ba" else waxman
    top = gen(args.peers, seed=args.seed)
    engine = SimEngine(top, SimParams(k=args.k, seed=args.seed))
    spec = QuerySpec(origins=(0,))
    print(f"overlay: {args.topology}, {args.peers} peers, "
          f"avg degree {top.avg_degree():.2f}, "
          f"TTL*={engine.plan.auto_ttl(0)}")

    print("\n-- algorithms (paper §5.2/5.3) --")
    print(f"{'policy':10s} {'messages':>10s} {'bytes':>12s} "
          f"{'resp (s)':>9s} {'accuracy':>8s}")
    for name in ("fd-dynamic", "cn-star", "cn"):
        met = engine.run(spec, name).query_metrics()
        print(f"{name:10s} {met.total_messages:>10,} "
              f"{met.total_bytes:>12,} "
              f"{met.response_time_s:>9.1f} {met.accuracy:>8.2f}")

    print("\n-- forward strategies (paper §3.3) --")
    for name in ("fd-basic", "fd-st1", "fd-st1+2"):
        met = engine.run(spec, name).query_metrics()
        print(f"{name:10s} m_fw={met.m_fw:>8,}  total "
              f"bytes={met.total_bytes:>10,}")

    print("\n-- statistics heuristic (paper Fig 7) --")
    for z in (0.4, 0.8, 1.0):
        res = engine.run(spec, get_policy("fd-stats").variant(z=z))
        print(f"z={z:.1f}: comm -{res.extras['comm_reduction']:.0%}, "
              f"accuracy {res.extras['accuracy']:.0%}")

    print("\n-- churn (paper Fig 8) --")
    basic = get_policy("fd-st1+2")          # no urgent lists / rerouting
    dyn = get_policy("fd-dynamic")
    for lt in (1, 4, 30):
        mb = engine.run(spec, basic.variant(
            lifetime_mean_s=lt * 60.0)).query_metrics()
        md = engine.run(spec, dyn.variant(
            lifetime_mean_s=lt * 60.0)).query_metrics()
        print(f"lifetime {lt:>3}min: FD-Basic acc={mb.accuracy:.2f}  "
              f"FD-Dynamic acc={md.accuracy:.2f}")


if __name__ == "__main__":
    main()
