"""FD top-k gradient compression across pods (DCN axis) with error
feedback — the paper's score-lists + Lemma-4 k-inflation applied to
distributed optimization.

Run:  PYTHONPATH=src python examples/grad_compression.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro.optim.compress import (compress_init, compression_ratio,
                                  fd_sparse_allreduce, inflate_k)

from repro.jaxcompat import make_mesh
mesh = make_mesh((8,), ("pod",))
print(f"pods: {dict(mesh.shape)['pod']}")

# a synthetic "gradient" with heavy-tailed structure (like real grads)
key = jax.random.PRNGKey(0)
g = {"w": jax.random.laplace(key, (256, 128)) ** 3}
dense_mean = g["w"]                  # same grad on each pod -> mean == g

ef = compress_init(g)
err_prev = None
sent_frac = 0.002
for rnd in range(6):
    gi = g if rnd == 0 else {"w": jnp.zeros_like(g["w"])}
    g_hat, ef = fd_sparse_allreduce(gi, ef, mesh, axis="pod",
                                    k_frac=sent_frac, p_drop=0.05)
    if rnd == 0:
        acc = g_hat["w"]
    else:
        acc = acc + g_hat["w"]       # error feedback drains the residual
    err = float(jnp.linalg.norm(acc - dense_mean)
                / jnp.linalg.norm(dense_mean))
    print(f"round {rnd}: relative error {err:.4f}")
    assert err_prev is None or err <= err_prev + 1e-6
    err_prev = err

n = g["w"].size
k = inflate_k(int(sent_frac * n), 0.05)
print(f"\nbytes per DCN round: dense={4 * n:,}  fd_topk={8 * k:,} "
      f"(k={k}, Lemma-4 inflated for 5% pod drop)")
print(f"compression ratio: {compression_ratio(n, k, 8):.0f}x")
print("grad_compression OK")
