"""Unified engine API quickstart (README § "Unified engine API").

One ``QuerySpec``, every policy in the registry, one compiled
``NetworkPlan`` — and the same QuerySpec/Policy surface again on a JAX
device mesh via ``DeviceEngine``.

Run:  PYTHONPATH=src python examples/engine_quickstart.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import time

import numpy as np

from repro.engine import (DeviceEngine, QuerySpec, SimEngine,
                          available_policies, get_policy)
from repro.p2psim import SimParams, barabasi_albert

# ---- 1. sim backend: the whole algorithm family, one engine --------------
top = barabasi_albert(400, m=2, seed=0)
engine = SimEngine(top, SimParams(seed=0))     # compiles the NetworkPlan
spec = QuerySpec(origins=(0, 7, 42), n_trials=4)

print(f"{'policy':10s} {'bytes':>12s} {'messages':>10s} "
      f"{'resp (s)':>9s} {'acc':>5s}")
for name in available_policies():
    if name == "fd-stats":                     # two-round heuristic
        res = engine.run(QuerySpec(origins=(0,)), name)
        print(f"{name:10s} comm -{res.extras['comm_reduction']:.0%} at "
              f"accuracy {res.extras['accuracy']:.0%} "
              f"(two rounds, z={res.extras['z']})")
        continue
    s = engine.run(spec, name).summary()
    print(f"{name:10s} {s['mean_total_bytes']:>12,.0f} "
          f"{s['mean_total_messages']:>10,.0f} "
          f"{s['mean_response_time_s']:>9.1f} {s['mean_accuracy']:>5.2f}")

# churn is a policy knob, not a new API
res = engine.run(spec, get_policy("fd-dynamic").variant(
    lifetime_mean_s=60.0))
print(f"{'+churn':10s} accuracy {res.metrics.accuracy.mean():.2f} "
      "(60 s mean lifetime)")

# ---- 2. the compiled NetworkPlan persists across runs --------------------
t0 = time.perf_counter()
engine.run(spec)
warm = time.perf_counter() - t0
t0 = time.perf_counter()
SimEngine(top, SimParams(seed=0)).run(spec)    # rebuilds the plan
cold = time.perf_counter() - t0
print(f"\nNetworkPlan reuse: cold {cold * 1e3:.1f} ms -> "
      f"warm {warm * 1e3:.1f} ms "
      f"({engine.plan.cache_info()['origin_statics']} origin statics "
      "cached)")

# ---- 3. jitted backend: same surface, same bits, XLA sweeps --------------
jit_engine = SimEngine(top, SimParams(seed=0), backend="jax")
spec_small = QuerySpec(origins=(0,), n_trials=2)
res_np = engine.run(spec_small)
res_jx = jit_engine.run(spec_small)          # compiles once per tree
assert res_jx.backend == "sim-jax"
assert np.array_equal(res_jx.metrics.response_time_s,
                      res_np.metrics.response_time_s)   # identical bits
t0 = time.perf_counter()
jit_engine.run(spec_small)                   # warm: jit + plan cached
print(f"\n[jax] backend bit-exact vs numpy ✓  warm run "
      f"{(time.perf_counter() - t0) * 1e3:.0f} ms")

# churn runs IN the jitted sweep too — deaths, urgent lists and §4.2
# dead-parent rerouting are validity masks over the plan's static
# reroute tables, so a volatile overlay costs no numpy fallback
churn_pol = get_policy("fd-dynamic").variant(lifetime_mean_s=60.0)
res_cj = jit_engine.run(spec_small, churn_pol)
res_cn = engine.run(spec_small, churn_pol)
assert res_cj.backend_used == "sim-jax"      # no silent fallback
assert np.array_equal(res_cj.metrics.accuracy, res_cn.metrics.accuracy)
print(f"[jax] churn (60 s lifetimes) in-XLA ✓  accuracy "
      f"{res_cj.metrics.accuracy.mean():.2f} vs "
      f"{res_jx.metrics.accuracy.mean():.2f} static "
      f"(backend_used={res_cj.backend_used})")

# ---- 4. device backend: same surface over shard_map collectives ----------
import jax

from repro.jaxcompat import make_mesh

dev = DeviceEngine(make_mesh((8,), ("model",)), schedule="halving")
scores = jax.random.normal(jax.random.PRNGKey(0), (2, 4096))
res = dev.run(QuerySpec(k=10), "fd-dynamic", scores=scores)
ref_vals, _ = jax.lax.top_k(scores, 10)
assert np.allclose(np.asarray(res.values), np.asarray(ref_vals),
                   atol=1e-6)
rows = jax.random.normal(jax.random.PRNGKey(1), (4096, 16))
got = dev.run(QuerySpec(k=10), "fd-dynamic", scores=scores[0], rows=rows)
print("\n[device] fd == global top-k ✓  retrieved rows "
      f"{np.asarray(got.rows).shape}; "
      f"model bytes fd={res.extras['model_bytes']:,} vs "
      f"cn={dev.run(QuerySpec(k=10), 'cn', scores=scores).extras['model_bytes']:,}")

# ---- 5. topology suite: BRITE-style families + per-edge latencies --------
# (docs/TOPOLOGIES.md has the full catalogue)
from repro.p2psim import SimParams, available_topologies, build_topology

print(f"\ntopology registry: {', '.join(available_topologies())}")
hier = build_topology("hierarchical", 2000, seed=7)   # AS-level Waxman
eng = SimEngine(hier, SimParams(seed=0))              # over router BA
spec_t = QuerySpec(origins=(0, 1), n_trials=2)
for lm in ("iid", "edge"):       # paper Table-1 draw vs BRITE distance
    s = eng.run(QuerySpec(origins=(0, 1), n_trials=2, latency_model=lm),
                "fd-dynamic")
    print(f"[{s.topology}] latency_model={s.latency_model:4s} "
          f"response {s.metrics.response_time_s.mean():.2f} s "
          f"(m_bw {s.metrics.m_bw.mean():,.0f})")
# per-edge latencies keep every backend bit-exact, like everything else
jx = SimEngine(hier, SimParams(seed=0, latency_model="edge"),
               backend="jax").run(spec_t, "fd-dynamic")
np_ = SimEngine(hier, SimParams(seed=0, latency_model="edge")).run(
    spec_t, "fd-dynamic")
assert np.array_equal(jx.metrics.response_time_s,
                      np_.metrics.response_time_s)
print("[topologies] edge-latency model bit-exact numpy == jax ✓")

# ---- 6. serving: run_many batching + an always-on QueryServer ------------
# (docs/SERVING.md covers the server lifecycle and batching rules)
from repro.engine import QueryServer, ServerConfig

specs = [QuerySpec(origins=(o,), seed=i)
         for i, o in enumerate((0, 7, 42, 99, 3, 12, 5, 31))]
pols = ["fd-dynamic", "cn"] * 4
fused = engine.run_many(specs, pols)       # one sweep per policy group
solo = [engine.run(s, p) for s, p in zip(specs, pols)]
assert all(np.array_equal(f.metrics.b_fw, s.metrics.b_fw)
           for f, s in zip(fused, solo))   # batching changes no bits
print(f"\n[serve] run_many fused 8 requests into sweeps of "
      f"{sorted({r.batch_size for r in fused})} — bit-exact vs run() ✓")

with QueryServer(engine, ServerConfig(max_batch=8)) as server:
    handles = [server.submit(s, p) for s, p in zip(specs, pols)]
    results = [h.result(timeout=30) for h in handles]
    m = server.metrics()
assert all(np.array_equal(r.metrics.b_fw, s.metrics.b_fw)
           for r, s in zip(results, solo))
print(f"[serve] QueryServer served {m.served}/8, mean batch "
      f"{m.mean_batch:.1f}, p50 latency "
      f"{m.latency.p50_s * 1e3:.1f} ms")
print("engine quickstart OK")
