"""End-to-end serving driver (the paper's kind of workload): batched
requests -> prefill -> decode loop with FD top-k sampling over the
model-sharded vocabulary, comparing FD vs the CN/CN* baselines.

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch qwen2-0.5b]
      (adds 8 fake host devices so the model axis is real)
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--model-par", type=int, default=4)
    args = ap.parse_args()

    from repro.launch import serve as serve_mod
    for alg in ("fd", "cn", "cn_star"):
        sys.argv = ["serve", "--arch", args.arch, "--smoke",
                    "--batch", str(args.batch),
                    "--prompt-len", str(args.prompt_len),
                    "--gen", str(args.gen),
                    "--model-par", str(args.model_par),
                    "--algorithm", alg]
        t0 = time.time()
        serve_mod.main()
        print(f"  -> {alg} end-to-end {time.time() - t0:.1f}s\n")


if __name__ == "__main__":
    main()
