"""Quickstart: the paper's Top-k query in 3 settings, in ~30 seconds.

 1. the P2P overlay simulation (the paper itself),
 2. the distributed FD top-k primitive on a device mesh,
 3. a tiny LM decode step that samples through FD.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

# ---- 1. the paper: a Top-k query over an unstructured overlay -----------
from repro.engine import QuerySpec, SimEngine
from repro.p2psim import SimParams, barabasi_albert

top = barabasi_albert(500, m=2, seed=0)
engine = SimEngine(top, SimParams(seed=0))    # NetworkPlan compiled once
for pol in ("fd-dynamic", "cn", "cn-star"):
    met = engine.run(QuerySpec(origins=(0,)), pol).query_metrics()
    print(f"[p2p ] {pol:10s} bytes={met.total_bytes:>10,}  "
          f"resp={met.response_time_s:8.1f}s  acc={met.accuracy:.2f}")

# ---- 2. FD as a mesh collective -----------------------------------------
from repro.core.fd import comm_bytes, fd_topk

from repro.jaxcompat import make_mesh
mesh = make_mesh((8,), ("model",))
scores = jax.random.normal(jax.random.PRNGKey(0), (2, 65536))
vals, idx = fd_topk(scores, 10, mesh, "model", schedule="halving")
ref_vals, ref_idx = jax.lax.top_k(scores, 10)
assert np.allclose(np.asarray(vals), np.asarray(ref_vals), atol=1e-6)
print(f"[mesh] fd == global top-k ✓   bytes: fd={comm_bytes('fd', 8, 8192, 10):,} "
      f"cn={comm_bytes('cn', 8, 8192, 10):,} "
      f"cn*={comm_bytes('cn_star', 8, 8192, 10):,}")

# ---- 3. FD sampling inside a model decode step ---------------------------
from repro.configs.base import get_config, smoke_config
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.runtime.steps import make_serve_step

cfg = smoke_config(get_config("qwen2-0.5b"))
hmesh = make_host_mesh(model=min(4, len(jax.devices())))
from repro.jaxcompat import use_mesh
ctx = use_mesh(hmesh)
ctx.__enter__()
params = M.init_params(jax.random.PRNGKey(0), cfg, max_seq=64)
state = M.init_decode_state(cfg, batch=2, s_max=16, cache_dtype=jnp.float32)
step = jax.jit(make_serve_step(cfg, hmesh, k=8, batch_axes=("data",)))
tok = jnp.ones((2, 1), jnp.int32)
for i in range(4):
    tok, state = step(params, state, tok, jax.random.PRNGKey(i))
print(f"[lm  ] decoded via FD sampling on mesh {dict(hmesh.shape)}: "
      f"{np.asarray(tok).ravel().tolist()}")
ctx.__exit__(None, None, None)
print("quickstart OK")
