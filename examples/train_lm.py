"""Train a small LM end-to-end with the full production stack: sharded
train step, checkpoint/restart, straggler watchdog, synthetic pipeline.

Default is CPU-sized (a few minutes); ``--full`` trains the ~100M-param
config (use on real accelerators).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--full]
"""
import argparse
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="~100M params (accelerator-sized)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    from repro.configs.base import ModelConfig, _REGISTRY
    # a dedicated small config registered on the fly
    small = ModelConfig(
        name="lm-example", family="dense",
        n_layers=4 if not args.full else 12,
        d_model=128 if not args.full else 768,
        n_heads=4 if not args.full else 12,
        n_kv_heads=2 if not args.full else 4,
        head_dim=32 if not args.full else 64,
        d_ff=512 if not args.full else 3072,
        vocab_size=2048 if not args.full else 32768,
        tie_embeddings=True, param_dtype="float32",
        compute_dtype="float32")
    _REGISTRY[small.name] = small

    from repro.launch import train as train_mod
    sys.argv = ["train", "--arch", small.name, "--steps", str(args.steps),
                "--batch", "8", "--seq", "128", "--lr", "1e-3",
                "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
                "--log-every", "20", "--watchdog"]
    losses = train_mod.main()
    drop = losses[0] - losses[-1]
    print(f"loss drop over {args.steps} steps: {drop:.3f} "
          f"({losses[0]:.3f} -> {losses[-1]:.3f})")
    assert drop > 0, "model failed to learn"


if __name__ == "__main__":
    main()
