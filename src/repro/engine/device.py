"""DeviceEngine — the QuerySpec/Policy surface over JAX shard_map.

Wraps the ``fd_topk`` / ``fd_topk_gather`` collectives (devices play
peers, ppermute schedules play the merge-and-backward phase) behind the
same engine API as ``SimEngine``.  The compiled plan here is the jitted
shard_map program: callables are cached per (path, k, algorithm,
schedule) and XLA's own shape-keyed cache makes repeated ``run`` calls
on the same mesh reuse the compiled executable.

Policy mapping: every ``fd-*`` policy lowers to the FD collective (the
jitted program *is* the query — compile-time flooding makes the §3.3
forward strategies and §4 churn handling moot on a reliable fabric);
``cn`` / ``cn-star`` lower to the paper's baselines; ``fd-stats`` has
no device backend.
"""
from __future__ import annotations

import functools
from typing import Optional, Union

from repro.engine.api import Policy, QuerySpec, TopKResult, get_policy

_DEVICE_ALGOS = ("fd", "cn", "cn_star")


class DeviceEngine:
    """Unified Top-k engine backend over a JAX device mesh."""

    backend = "device"

    def __init__(self, mesh=None, axis: str = "model", *,
                 schedule: str = "halving", batch_axes=None,
                 use_pallas: bool = False):
        """Build the engine (and bind ``mesh`` when given)."""
        self.axis = axis
        self.schedule = schedule
        self.batch_axes = batch_axes
        self.use_pallas = use_pallas
        self.mesh = None
        self._compiled: dict = {}
        if mesh is not None:
            self.prepare(mesh)

    def prepare(self, mesh):
        """Bind (or rebind) the device mesh; drops stale compiled fns."""
        self.mesh = mesh
        self._compiled.clear()
        return mesh

    @property
    def axis_size(self) -> int:
        """Device count along the engine's collective axis."""
        return dict(self.mesh.shape)[self.axis]

    def _fn(self, path: str, k: int, algorithm: str):
        import jax

        from repro.core import fd
        key = (path, k, algorithm, self.schedule)
        fn = self._compiled.get(key)
        if fn is None:
            if path == "gather":
                base = functools.partial(
                    fd.fd_topk_gather, k=k, mesh=self.mesh, axis=self.axis,
                    schedule=self.schedule, batch_axes=self.batch_axes)
            else:
                base = functools.partial(
                    fd.fd_topk, k=k, mesh=self.mesh, axis=self.axis,
                    schedule=self.schedule, algorithm=algorithm,
                    use_pallas=self.use_pallas, batch_axes=self.batch_axes)
            fn = jax.jit(base)
            self._compiled[key] = fn
        return fn

    def run(self, spec: Optional[QuerySpec] = None,
            policy: Union[str, Policy] = "fd-dynamic", *,
            scores, rows=None) -> TopKResult:
        """Top-k of ``scores`` (sharded over ``axis``) under ``policy``.

        ``rows`` — optional (N, d) sharded table: runs the phase-4
        data-retrieval gather and fills ``TopKResult.rows`` (FD only).
        Only ``spec.k`` is read from the spec on this backend.
        """
        if self.mesh is None:
            raise RuntimeError("call DeviceEngine.prepare(mesh) first")
        spec = spec if spec is not None else QuerySpec()
        pol = get_policy(policy)
        if pol.algorithm not in _DEVICE_ALGOS:
            raise ValueError(
                f"policy {pol.name!r} (algorithm {pol.algorithm!r}) has no "
                f"device backend; use one of {_DEVICE_ALGOS}")
        k = spec.k if spec.k is not None else 20
        n = scores.shape[-1]
        extras = {}
        if n % self.axis_size == 0:
            from repro.core.fd import comm_bytes
            extras["model_bytes"] = comm_bytes(
                pol.algorithm, self.axis_size, n // self.axis_size, k,
                schedule=self.schedule)
        if rows is not None:
            if pol.algorithm != "fd":
                raise ValueError(
                    "the data-retrieval gather path is FD-only "
                    "(CN ships whole shards, not k rows)")
            vals, idx, got = self._fn("gather", k, pol.algorithm)(scores,
                                                                  rows)
            return TopKResult(policy=pol.name, backend=self.backend, k=k,
                              values=vals, indices=idx, rows=got,
                              extras=extras)
        vals, idx = self._fn("topk", k, pol.algorithm)(scores)
        return TopKResult(policy=pol.name, backend=self.backend, k=k,
                          values=vals, indices=idx, extras=extras)
