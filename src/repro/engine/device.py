"""DeviceEngine — the QuerySpec/Policy surface over JAX shard_map.

Wraps the ``fd_topk`` / ``fd_topk_gather`` collectives (devices play
peers, ppermute schedules play the merge-and-backward phase) behind the
same engine API as ``SimEngine``.  The compiled plan here is the jitted
shard_map program: callables are cached per (path, k, algorithm,
schedule) and XLA's own shape-keyed cache makes repeated ``run`` calls
on the same mesh reuse the compiled executable.

Policy mapping: every ``fd-*`` policy lowers to the FD collective (the
jitted program *is* the query — compile-time flooding makes the §3.3
forward strategies and §4 churn handling moot on a reliable fabric);
``cn`` / ``cn-star`` lower to the paper's baselines; ``fd-stats`` has
no device backend.
"""
from __future__ import annotations

import functools
import time
from typing import List, Optional, Sequence, Union

from repro.engine.api import (PRECISIONS, Engine, Policy, QuerySpec,
                              TopKResult, get_policy)

_DEVICE_ALGOS = ("fd", "cn", "cn_star")


class DeviceEngine(Engine):
    """Unified Top-k engine backend over a JAX device mesh.

    ``precision``: ``None`` (default) runs the collectives in whatever
    dtype the caller's score arrays carry — the historical behavior.
    ``"f64"`` / ``"f32"`` / ``"bf16"`` casts the inputs once before
    dispatch and records the mode on ``TopKResult.precision`` — the
    same opt-in surface as ``SimEngine(backend="jax")``.  Note the
    collectives' local top-k deliberately computes in f32
    (:mod:`repro.kernels.topk`), so ``"bf16"`` QUANTIZES the scores to
    bf16 and then merges in f32 — identical bits to casting the scores
    by hand — and ``"f64"`` needs ``enable_x64`` to survive the
    initial ``asarray``.
    """

    backend = "device"

    def __init__(self, mesh=None, axis: str = "model", *,
                 schedule: str = "halving", batch_axes=None,
                 use_pallas: bool = False,
                 precision: Optional[str] = None):
        """Build the engine (and bind ``mesh`` when given)."""
        if precision is not None and precision not in PRECISIONS:
            raise ValueError(
                f"precision must be one of {PRECISIONS} (or None), "
                f"got {precision!r}")
        self.axis = axis
        self.schedule = schedule
        self.batch_axes = batch_axes
        self.use_pallas = use_pallas
        self.precision = precision
        self.mesh = None
        self._compiled: dict = {}
        if mesh is not None:
            self.prepare(mesh)

    def _cast(self, scores):
        """Scores in the engine's requested precision (None = as-is)."""
        if self.precision is None:
            return scores
        import jax.numpy as jnp

        from repro.engine.precision import np_dtype
        return jnp.asarray(scores, np_dtype(self.precision))

    def prepare(self, mesh):
        """Bind (or rebind) the device mesh; drops stale compiled fns."""
        self.mesh = mesh
        self._compiled.clear()
        return mesh

    @property
    def axis_size(self) -> int:
        """Device count along the engine's collective axis."""
        return dict(self.mesh.shape)[self.axis]

    def _fn(self, path: str, k: int, algorithm: str):
        import jax

        from repro.core import fd
        key = (path, k, algorithm, self.schedule)
        fn = self._compiled.get(key)
        if fn is None:
            if path == "gather":
                base = functools.partial(
                    fd.fd_topk_gather, k=k, mesh=self.mesh, axis=self.axis,
                    schedule=self.schedule, batch_axes=self.batch_axes)
            else:
                base = functools.partial(
                    fd.fd_topk, k=k, mesh=self.mesh, axis=self.axis,
                    schedule=self.schedule, algorithm=algorithm,
                    use_pallas=self.use_pallas, batch_axes=self.batch_axes)
            fn = jax.jit(base)
            self._compiled[key] = fn
        return fn

    def run(self, spec: Optional[QuerySpec] = None,
            policy: Union[str, Policy] = "fd-dynamic", *,
            scores, rows=None) -> TopKResult:
        """Top-k of ``scores`` (sharded over ``axis``) under ``policy``.

        ``rows`` — optional (N, d) sharded table: runs the phase-4
        data-retrieval gather and fills ``TopKResult.rows`` (FD only).
        Only ``spec.k`` is read from the spec on this backend.  This is
        the batch-of-1 case of :meth:`run_many`.
        """
        spec = spec if spec is not None else QuerySpec()
        return self.run_many([spec], [policy], scores=[scores],
                             rows=None if rows is None else [rows])[0]

    def run_many(self, specs: Sequence[QuerySpec],
                 policies: Union[str, Policy,
                                 Sequence[Union[str, Policy]]]
                 = "fd-dynamic", *, scores: Sequence,
                 rows: Optional[Sequence] = None) -> List[TopKResult]:
        """Execute a request batch; ``scores[i]`` answers ``specs[i]``.

        Requests with 1-D score vectors of identical shape/dtype, the
        same effective ``k`` and the same lowered collective (all
        ``fd-*`` policies share the FD program) are STACKED onto one
        batched collective call — one jitted program executes the whole
        group, each row recovering exactly the bits its solo call would
        produce (the collectives are elementwise per batch row).
        Gather-path requests (``rows``) and pre-batched score arrays run
        individually.  ``rows`` is an optional per-spec sequence
        (``None`` entries take the plain top-k path).
        """
        if self.mesh is None:
            raise RuntimeError("call DeviceEngine.prepare(mesh) first")
        pols = self._zip_policies(specs, policies)
        scores = [self._cast(s) for s in scores]
        row_seq = list(rows) if rows is not None else [None] * len(specs)
        if len(scores) != len(specs) or len(row_seq) != len(specs):
            raise ValueError(
                f"need one scores (and rows) entry per spec: "
                f"{len(specs)} specs, {len(scores)} scores, "
                f"{len(row_seq)} rows")
        results: List[Optional[TopKResult]] = [None] * len(specs)
        groups: dict = {}               # exec signature -> [index]
        for i, (spec, pol) in enumerate(zip(specs, pols)):
            if pol.algorithm not in _DEVICE_ALGOS:
                raise ValueError(
                    f"policy {pol.name!r} (algorithm {pol.algorithm!r}) "
                    f"has no device backend; use one of {_DEVICE_ALGOS}")
            k = spec.k if spec.k is not None else 20
            s = scores[i]
            if row_seq[i] is not None or getattr(s, "ndim", 0) != 1:
                results[i] = self._run_one(pol, k, s, row_seq[i])
                continue
            key = (pol.algorithm, k, s.shape, str(getattr(s, "dtype", "")))
            groups.setdefault(key, []).append(i)
        for (algorithm, k, _, _), idxs in groups.items():
            if len(idxs) == 1:
                i = idxs[0]
                results[i] = self._run_one(pols[i], k, scores[i], None)
                continue
            import jax
            import jax.numpy as jnp
            stacked = jnp.stack([scores[i] for i in idxs])
            t0 = time.perf_counter()
            fn = self._fn("topk", k, algorithm)
            compile_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            vals, idx = fn(stacked)
            jax.block_until_ready(vals)
            run_s = time.perf_counter() - t0
            for b, i in enumerate(idxs):
                res = self._result(pols[i], k, scores[i], vals[b], idx[b],
                                   None)
                res.compile_s, res.run_s = compile_s, run_s
                res.batch_size = len(idxs)
                results[i] = res
        return results

    def _run_one(self, pol: Policy, k: int, scores, rows) -> TopKResult:
        """One unfused collective call (gather / pre-batched / solo)."""
        import jax
        t0 = time.perf_counter()
        if rows is not None:
            if pol.algorithm != "fd":
                raise ValueError(
                    "the data-retrieval gather path is FD-only "
                    "(CN ships whole shards, not k rows)")
            fn = self._fn("gather", k, pol.algorithm)
            compile_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            vals, idx, got = fn(scores, rows)
            jax.block_until_ready(vals)
            res = self._result(pol, k, scores, vals, idx, got)
        else:
            fn = self._fn("topk", k, pol.algorithm)
            compile_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            vals, idx = fn(scores)
            jax.block_until_ready(vals)
            res = self._result(pol, k, scores, vals, idx, None)
        res.compile_s, res.run_s = compile_s, time.perf_counter() - t0
        return res

    def _result(self, pol: Policy, k: int, scores, vals, idx,
                got) -> TopKResult:
        """Assemble a TopKResult (+ the comm-model bytes extra)."""
        # precision=None runs in the caller's dtype; report what ran.
        prec = self.precision or {
            "float32": "f32", "bfloat16": "bf16"}.get(
                str(getattr(vals, "dtype", "")), "f64")
        extras = {}
        n = scores.shape[-1]
        if n % self.axis_size == 0:
            from repro.core.fd import comm_bytes
            extras["model_bytes"] = comm_bytes(
                pol.algorithm, self.axis_size, n // self.axis_size, k,
                schedule=self.schedule)
        return TopKResult(policy=pol.name, backend=self.backend, k=k,
                          values=vals, indices=idx, rows=got,
                          precision=prec,
                          extras=extras)
