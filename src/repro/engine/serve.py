"""QueryServer — the always-on serving layer over long-lived engines.

The paper validates FD under CONCURRENT query load (a 64-node cluster
serving many users at once); this module is that deployment shape for
the reproduction.  A ``QueryServer`` hosts warm, long-lived engines
(``SimEngine`` / ``SimEngine(backend="jax")`` / ``DeviceEngine``) behind
a bounded request queue and a dynamic batcher:

  * **requests** are ``(QuerySpec, policy, engine)`` triples submitted
    from any thread; ``submit`` returns a :class:`QueryHandle` future;
  * a single **dispatcher thread** pulls a batch off the queue — up to
    ``max_batch`` requests, waiting at most ``batch_window_s`` after the
    first — and hands each engine's share to ``Engine.run_many``, which
    coalesces compatible specs onto ONE batched sweep (reusing the
    plan's cached ``NetworkPlan`` / ``DepthSlices`` and jit traces), so
    N concurrent queries on a warm overlay cost one sweep;
  * the queue is **bounded**: when it is full, ``submit`` sheds the
    request immediately and deterministically with
    :class:`ServerOverloaded` — the overload signal IS the error, no
    request is silently dropped;
  * every request may carry a **timeout**: a request whose deadline has
    passed when the dispatcher picks it up completes with
    :class:`RequestTimeout` instead of executing (queueing time is the
    only thing a shed saves — execution is never interrupted mid-sweep);
  * **serving metrics** — queue depth, batch-size histogram, shed /
    timeout counters, per-request queue / compile / run timings — are
    aggregated continuously and snapshot via :meth:`QueryServer.metrics`.

Batching changes no bits: results are entry-wise identical to a
sequential ``engine.run`` per request (``Engine.run_many``'s contract,
asserted by tests/test_serving.py and the ``serving`` benchmark suite).

    from repro.engine import QueryServer, QuerySpec, SimEngine

    server = QueryServer(SimEngine(topology, backend="jax"))
    with server:                               # start() / stop()
        handles = [server.submit(QuerySpec(origins=(o,), seed=s), "cn")
                   for s, o in enumerate(origins)]
        results = [h.result(timeout=5) for h in handles]
    server.metrics().batch_hist                # {sweep size: count}

``benchmarks/loadgen.py`` drives this layer at ramping concurrency and
emits the ``BENCH_serving.json`` suite; ``python -m repro.launch.serve
overlay`` is the process entrypoint.  See docs/SERVING.md.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.engine.api import Engine, Policy, QuerySpec, TopKResult


class ServerError(RuntimeError):
    """Base class for serving-layer failures."""


class ServerOverloaded(ServerError):
    """The bounded request queue was full: the request was shed."""


class RequestTimeout(ServerError):
    """The request's deadline expired before its sweep was dispatched."""


class ServerClosed(ServerError):
    """The server was stopped before the request could execute."""


@dataclasses.dataclass(frozen=True)
class LatencyStats:
    """Submit-to-completion latency percentiles over served requests."""

    mean_s: float
    p50_s: float
    p95_s: float
    p99_s: float


@dataclasses.dataclass(frozen=True)
class PhaseStats:
    """Mean / max of one per-request phase timing (queue wait, run)."""

    mean: float
    max: float


@dataclasses.dataclass(frozen=True)
class ServerMetrics:
    """Typed snapshot of :meth:`QueryServer.metrics`.

    Counters count REQUESTS (``submitted`` includes everything accepted
    into the queue; ``shed`` requests were never queued).  ``batch_hist``
    histograms ``TopKResult.batch_size`` over served requests — how many
    requests shared each executed sweep; ``dispatch_hist`` histograms
    how many requests each dispatcher cycle pulled.  ``latency`` /
    ``queue_s`` / ``run_s`` are ``None`` until a request completes.

    ``as_dict()`` is the back-compat escape hatch: it returns exactly
    the flat dict the pre-typed ``metrics()`` produced (timing keys
    absent when no request has completed), so existing JSON emitters
    keep working unchanged.
    """

    submitted: int
    served: int
    shed: int
    timed_out: int
    failed: int
    queue_depth: int
    max_queue_depth: int
    batch_hist: Dict[int, int]
    dispatch_hist: Dict[int, int]
    mean_batch: float
    max_batch: int
    latency: Optional[LatencyStats] = None
    queue_s: Optional[PhaseStats] = None
    run_s: Optional[PhaseStats] = None

    def as_dict(self) -> dict:
        """The legacy flat-dict shape (see class docstring)."""
        out = {
            "submitted": self.submitted, "served": self.served,
            "shed": self.shed, "timed_out": self.timed_out,
            "failed": self.failed, "queue_depth": self.queue_depth,
            "max_queue_depth": self.max_queue_depth,
            "batch_hist": dict(self.batch_hist),
            "dispatch_hist": dict(self.dispatch_hist),
            "mean_batch": self.mean_batch, "max_batch": self.max_batch,
        }
        if self.latency is not None:
            out["latency"] = dataclasses.asdict(self.latency)
            out["queue_s"] = dataclasses.asdict(self.queue_s)
            out["run_s"] = dataclasses.asdict(self.run_s)
        return out


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Serving knobs.

    * ``max_queue`` — bound of the request queue; a full queue sheds
      (``submit`` raises :class:`ServerOverloaded`).
    * ``max_batch`` — most requests one dispatcher cycle hands to
      ``run_many`` (the dynamic batcher's ceiling).
    * ``batch_window_s`` — how long the dispatcher lingers after the
      first dequeued request to let concurrent arrivals coalesce.
      Immediately available requests are always drained regardless.
    * ``default_timeout_s`` — per-request deadline applied when
      ``submit`` passes none (``None`` = no deadline).
    """

    max_queue: int = 256
    max_batch: int = 64
    batch_window_s: float = 0.002
    default_timeout_s: Optional[float] = None


class QueryHandle:
    """Future for one submitted request.

    ``result(timeout)`` blocks until the dispatcher completes the
    request and returns its ``TopKResult`` (with ``queue_s`` /
    ``compile_s`` / ``run_s`` / ``batch_size`` filled in) or raises the
    request's failure (:class:`RequestTimeout`, :class:`ServerClosed`,
    or whatever the engine raised).
    """

    __slots__ = ("spec", "policy", "engine_name", "deadline", "t_submit",
                 "_event", "_result", "_error")

    def __init__(self, spec: QuerySpec, policy: Policy, engine_name: str,
                 deadline: Optional[float]):
        """Bind the request triple; the server completes the handle."""
        self.spec = spec
        self.policy = policy
        self.engine_name = engine_name
        self.t_submit = time.perf_counter()
        self.deadline = deadline
        self._event = threading.Event()
        self._result: Optional[TopKResult] = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        """True once the request completed (result or error)."""
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> TopKResult:
        """Block for the result; raise the request's failure if any."""
        if not self._event.wait(timeout):
            raise TimeoutError("request not completed within "
                               f"{timeout} s (still queued or running)")
        if self._error is not None:
            raise self._error
        return self._result

    def exception(self,
                  timeout: Optional[float] = None) -> \
            Optional[BaseException]:
        """Block for completion; return the failure (None on success)."""
        if not self._event.wait(timeout):
            raise TimeoutError("request not completed within "
                               f"{timeout} s (still queued or running)")
        return self._error

    # -- completion (server side) -----------------------------------------

    def _complete(self, result: Optional[TopKResult],
                  error: Optional[BaseException]) -> None:
        self._result, self._error = result, error
        self._event.set()


class QueryServer:
    """Long-lived query service over one or more warm engines.

    ``engines`` — a single :class:`~repro.engine.api.Engine` (registered
    under the name ``"default"``) or a dict naming several, e.g. one
    jitted ``SimEngine`` per hosted overlay.  Engines stay alive (and
    warm: compiled plans, depth slices, jit traces) for the server's
    whole lifetime — that is the point.

    The dispatcher is a single thread: one sweep executes at a time,
    which is exactly what dynamic batching wants (concurrent requests
    coalesce instead of contending).  ``submit`` is thread-safe and may
    be called before ``start`` — queued requests are served once the
    dispatcher runs (tests use this to exercise shedding
    deterministically).
    """

    def __init__(self, engines: Union[Engine, Dict[str, Engine]],
                 config: Optional[ServerConfig] = None):
        """Register ``engines`` and size the bounded queue."""
        if isinstance(engines, Engine):
            engines = {"default": engines}
        if not engines:
            raise ValueError("QueryServer needs at least one engine")
        self.engines: Dict[str, Engine] = dict(engines)
        self.config = config if config is not None else ServerConfig()
        self._queue: "queue.Queue[QueryHandle]" = queue.Queue(
            maxsize=self.config.max_queue)
        self._thread: Optional[threading.Thread] = None
        self._closed = False
        self._stop = threading.Event()
        self._lock = threading.Lock()
        self._counters = {"submitted": 0, "served": 0, "shed": 0,
                          "timed_out": 0, "failed": 0}
        self._batch_hist: Dict[int, int] = {}
        self._dispatch_sizes: Dict[int, int] = {}
        self._max_queue_depth = 0
        self._records: List[tuple] = []   # (total_s, queue_s, run_s)

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "QueryServer":
        """Start the dispatcher thread (idempotent)."""
        if self._closed:
            raise ServerClosed("server already stopped")
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._serve_loop, name="fd-query-server",
                daemon=True)
            self._thread.start()
        return self

    def stop(self, drain: bool = True,
             timeout: Optional[float] = None) -> None:
        """Stop accepting requests and shut the dispatcher down.

        ``drain=True`` serves everything already queued first;
        ``drain=False`` fails pending requests with
        :class:`ServerClosed`.
        """
        self._closed = True
        if self._thread is None:
            self._fail_pending(ServerClosed("server never started"))
            return
        if drain:
            self._queue.join()
        self._stop.set()
        self._thread.join(timeout)
        self._thread = None
        self._fail_pending(ServerClosed("server stopped"))

    def __enter__(self) -> "QueryServer":
        """Context manager: ``start`` on entry."""
        return self.start()

    def __exit__(self, *exc) -> None:
        """Context manager: draining ``stop`` on exit."""
        self.stop(drain=exc == (None, None, None))

    # -- client surface ----------------------------------------------------

    def submit(self, spec: Optional[QuerySpec] = None,
               policy: Union[str, Policy] = "fd-dynamic",
               engine: Optional[str] = None,
               timeout_s: Optional[float] = None) -> QueryHandle:
        """Enqueue one request; returns its :class:`QueryHandle`.

        Raises :class:`ServerOverloaded` IMMEDIATELY when the bounded
        queue is full (graceful shedding — the caller knows at submit
        time) and :class:`ServerClosed` after ``stop``.
        """
        if self._closed:
            raise ServerClosed("server is stopped")
        name = self._resolve_engine(engine)
        pol = self.engines[name]._zip_policies((None,), policy)[0]
        if timeout_s is None:
            timeout_s = self.config.default_timeout_s
        handle = QueryHandle(
            spec if spec is not None else QuerySpec(), pol, name,
            None if timeout_s is None
            else time.perf_counter() + timeout_s)
        try:
            self._queue.put_nowait(handle)
        except queue.Full:
            with self._lock:
                self._counters["shed"] += 1
            raise ServerOverloaded(
                f"request queue full ({self.config.max_queue} pending); "
                "request shed") from None
        with self._lock:
            self._counters["submitted"] += 1
            self._max_queue_depth = max(self._max_queue_depth,
                                        self._queue.qsize())
        return handle

    def query(self, spec: Optional[QuerySpec] = None,
              policy: Union[str, Policy] = "fd-dynamic",
              engine: Optional[str] = None,
              timeout_s: Optional[float] = None) -> TopKResult:
        """``submit`` + blocking ``result`` in one call."""
        return self.submit(spec, policy, engine, timeout_s).result()

    def warm(self, spec: Optional[QuerySpec] = None,
             policy: Union[str, Policy] = "fd-dynamic",
             engine: Optional[str] = None,
             batch_sizes: Optional[Sequence[int]] = None,
             **kwargs) -> TopKResult:
        """Run one query DIRECTLY on an engine (no queue) to populate
        its plan / trace caches before taking load.  Call before
        ``start`` or while the server is idle — engines are owned by
        the dispatcher thread once traffic flows.

        ``batch_sizes`` — optionally also pre-trace FUSED dispatch
        shapes: for each ``b`` the spec is replicated ``b`` times
        through ``run_many``, exactly the call the dispatcher makes for
        a coalesced batch of ``b`` identical requests.  The jax backend
        pads entry batches to power-of-two buckets, so warming
        ``(1, max_batch)`` covers every batch size in between — live
        dispatches then report ``compile_s == 0``."""
        name = self._resolve_engine(engine)
        eng = self.engines[name]
        if batch_sizes:
            res = None
            base = spec if spec is not None else QuerySpec()
            for b in batch_sizes:
                if b < 1:
                    raise ValueError(f"batch sizes must be >= 1, got {b}")
                res = eng.run_many([base] * int(b), policy, **kwargs)[-1]
            return res
        return eng.run(spec, policy, **kwargs)

    def metrics(self) -> ServerMetrics:
        """Snapshot of the serving counters and timing aggregates as a
        typed :class:`ServerMetrics` (``.as_dict()`` recovers the old
        flat-dict shape)."""
        with self._lock:
            counters = dict(self._counters)
            hist = dict(self._batch_hist)
            dispatch = dict(self._dispatch_sizes)
            depth_max = self._max_queue_depth
            rec = list(self._records)
        n = sum(hist.values())
        latency = queue_s = run_s = None
        if rec:
            arr = np.asarray(rec)
            latency = LatencyStats(
                mean_s=float(arr[:, 0].mean()),
                p50_s=float(np.percentile(arr[:, 0], 50)),
                p95_s=float(np.percentile(arr[:, 0], 95)),
                p99_s=float(np.percentile(arr[:, 0], 99)))
            queue_s = PhaseStats(mean=float(arr[:, 1].mean()),
                                 max=float(arr[:, 1].max()))
            run_s = PhaseStats(mean=float(arr[:, 2].mean()),
                               max=float(arr[:, 2].max()))
        return ServerMetrics(
            submitted=counters["submitted"], served=counters["served"],
            shed=counters["shed"], timed_out=counters["timed_out"],
            failed=counters["failed"],
            queue_depth=self._queue.qsize(),
            max_queue_depth=depth_max,
            batch_hist=hist, dispatch_hist=dispatch,
            mean_batch=(sum(s * c for s, c in hist.items()) / n
                        if n else 0.0),
            max_batch=max(hist) if hist else 0,
            latency=latency, queue_s=queue_s, run_s=run_s)

    # -- dispatcher --------------------------------------------------------

    def _resolve_engine(self, engine: Optional[str]) -> str:
        if engine is None:
            if len(self.engines) == 1:
                return next(iter(self.engines))
            raise ValueError(
                "several engines are hosted "
                f"({sorted(self.engines)}); name one")
        if engine not in self.engines:
            raise KeyError(f"unknown engine {engine!r}; hosted: "
                           f"{sorted(self.engines)}")
        return engine

    def _serve_loop(self) -> None:
        """Dispatcher: drain → coalesce (window) → run_many → complete."""
        cfg = self.config
        while not self._stop.is_set():
            try:
                first = self._queue.get(timeout=0.02)
            except queue.Empty:
                continue
            batch = [first]
            window_end = time.perf_counter() + cfg.batch_window_s
            while len(batch) < cfg.max_batch:
                try:                       # drain what's already there
                    batch.append(self._queue.get_nowait())
                    continue
                except queue.Empty:
                    pass
                rem = window_end - time.perf_counter()
                if rem <= 0:
                    break
                try:                       # linger for stragglers
                    batch.append(self._queue.get(timeout=rem))
                except queue.Empty:
                    break
            try:
                self._dispatch(batch)
            finally:
                for _ in batch:
                    self._queue.task_done()

    def _dispatch(self, batch: List[QueryHandle]) -> None:
        """Execute one dequeued batch: timeouts, per-engine run_many."""
        now = time.perf_counter()
        with self._lock:
            self._dispatch_sizes[len(batch)] = \
                self._dispatch_sizes.get(len(batch), 0) + 1
        by_engine: Dict[str, List[QueryHandle]] = {}
        for h in batch:
            if h.deadline is not None and now >= h.deadline:
                with self._lock:
                    self._counters["timed_out"] += 1
                h._complete(None, RequestTimeout(
                    "request waited "
                    f"{now - h.t_submit:.3f} s in queue, past its "
                    "deadline; dropped before execution"))
                continue
            by_engine.setdefault(h.engine_name, []).append(h)
        for name, handles in by_engine.items():
            try:
                results = self.engines[name].run_many(
                    [h.spec for h in handles],
                    [h.policy for h in handles])
            except Exception as e:             # noqa: BLE001 — the whole
                with self._lock:               # group shares the failure
                    self._counters["failed"] += len(handles)
                for h in handles:
                    h._complete(None, e)
                continue
            done = time.perf_counter()
            with self._lock:
                for h, res in zip(handles, results):
                    res.queue_s = now - h.t_submit
                    self._counters["served"] += 1
                    self._batch_hist[res.batch_size] = \
                        self._batch_hist.get(res.batch_size, 0) + 1
                    self._records.append(
                        (done - h.t_submit, res.queue_s, res.run_s))
                if len(self._records) > 200_000:   # bound the buffer
                    del self._records[:100_000]
            for h, res in zip(handles, results):
                h._complete(res, None)

    def _fail_pending(self, err: ServerError) -> None:
        """Complete everything still queued with ``err``."""
        while True:
            try:
                h = self._queue.get_nowait()
            except queue.Empty:
                return
            with self._lock:
                self._counters["failed"] += 1
            h._complete(None, err)
            self._queue.task_done()
