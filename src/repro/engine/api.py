"""QuerySpec / Policy / TopKResult — the engine's shared vocabulary.

The paper's FD framework is "a family of algorithms" (FD-Basic,
Strategy 1, Strategy 1+2, FD-Dynamic, the CN/CN* baselines, and the
§3.3 statistics heuristic).  This module separates the three concerns
that the legacy string-flag surface conflated:

  * a **QuerySpec** says WHAT to ask — k, origins, trials, RNG mode;
  * a **Policy** says HOW to execute it — one named member of the
    algorithm family, owning its forward / merge / churn knobs;
  * an **engine backend** says WHERE it runs — the numpy overlay
    simulator (``SimEngine``) or a JAX device mesh (``DeviceEngine``).

Every backend returns the same ``TopKResult``.
"""
from __future__ import annotations

import abc
import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.p2psim.metrics import BatchMetrics, QueryMetrics

RNG_MODES = ("shared", "independent")
LATENCY_MODELS = ("iid", "edge")
PRECISIONS = ("f64", "f32", "bf16")


@dataclasses.dataclass(frozen=True)
class QuerySpec:
    """What to ask: k, where queries originate, trials, RNG derivation.

    rng:
      * ``"shared"`` — one generator seeded ``seed`` issues batch-shaped
        draws (fast; a batch of one is bit-for-bit the scalar reference);
      * ``"independent"`` — entry (q, t) draws from its own generator
        seeded ``seed + q * n_trials + t`` and reproduces the scalar
        reference on that seed bit-for-bit, entry by entry.

    ``seeds`` — optional explicit (n_origins, n_trials) integer grid of
    per-entry seeds; implies ``rng="independent"``.

    ``latency_model`` — ``"iid"`` (paper Table 1: per-link N(200 ms,
    var) draws) or ``"edge"`` (BRITE distance-proportional latencies
    from the topology's embedding; needs a coordinate-carrying
    generator, see ``repro.p2psim.topologies``).  ``None`` defers to
    the engine's ``SimParams.latency_model``.

    ``precision`` — ``"f64"`` (default: the bit-exactness contract vs
    the scalar reference holds), or ``"f32"`` / ``"bf16"`` (jax backend
    only: the sweep runs in reduced precision and is validated against
    the f64 reference by a TOLERANCE contract — top-k set recall +
    score rtol, recorded in ``TopKResult.extras["tolerance"]`` — not
    bit-exactness).  ``None`` defers to the engine's configured
    precision.

    ``k`` / ``seed`` of None defer to the engine's ``SimParams``.  The
    device backend only reads ``k`` (scores are passed to ``run``).
    """

    origins: Tuple[int, ...] = (0,)
    n_trials: int = 1
    k: Optional[int] = None
    seed: Optional[int] = None
    rng: str = "shared"
    seeds: Optional[Any] = None
    latency_model: Optional[str] = None
    precision: Optional[str] = None

    def __post_init__(self):
        """Validate rng / n_trials / latency_model; seeds imply
        independent streams."""
        if self.rng not in RNG_MODES:
            raise ValueError(f"rng must be one of {RNG_MODES}, "
                             f"got {self.rng!r}")
        if self.n_trials < 1:
            raise ValueError(f"n_trials must be >= 1, got {self.n_trials}")
        if self.latency_model is not None \
                and self.latency_model not in LATENCY_MODELS:
            raise ValueError(
                f"latency_model must be one of {LATENCY_MODELS} (or "
                f"None to defer to SimParams), got {self.latency_model!r}")
        if self.precision is not None and self.precision not in PRECISIONS:
            raise ValueError(
                f"precision must be one of {PRECISIONS} (or None to "
                f"defer to the engine), got {self.precision!r}")
        if self.seeds is not None and self.rng != "independent":
            object.__setattr__(self, "rng", "independent")

    @property
    def independent(self) -> bool:
        """True when every entry draws from its own RNG stream."""
        return self.rng == "independent"


@dataclasses.dataclass(frozen=True)
class Policy:
    """How to execute: one named member of the paper's algorithm family.

    algorithm: ``"fd"`` | ``"cn"`` | ``"cn_star"`` | ``"fd-stats"``.
    ``strategy`` / ``dynamic`` are FD's forward- and merge-phase knobs
    (§3.3 strategies, §4 urgent lists + rerouting); ``lifetime_mean_s``
    is the churn knob (inf = static network); ``z`` is the fd-stats
    rank threshold (§3.3, Fig 7).
    """
    name: str
    algorithm: str
    strategy: str = "st1+2"
    dynamic: bool = True
    lifetime_mean_s: float = math.inf
    z: float = 0.8

    def variant(self, **overrides) -> "Policy":
        """A tweaked copy, e.g.
        ``get_policy("fd-dynamic").variant(lifetime_mean_s=60.0)``."""
        return dataclasses.replace(self, **overrides)


_REGISTRY: Dict[str, Policy] = {}


def register_policy(policy: Policy, *, overwrite: bool = False) -> Policy:
    """Add a policy to the global registry (error on duplicate names
    unless ``overwrite``)."""
    if not overwrite and policy.name in _REGISTRY:
        raise ValueError(f"policy {policy.name!r} already registered")
    _REGISTRY[policy.name] = policy
    return policy


def get_policy(policy) -> Policy:
    """Resolve a registered policy name; a ``Policy`` passes through."""
    if isinstance(policy, Policy):
        return policy
    try:
        return _REGISTRY[policy]
    except KeyError:
        raise KeyError(f"unknown policy {policy!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def available_policies() -> Tuple[str, ...]:
    """Registered policy names, in registration order."""
    return tuple(_REGISTRY)


# The family, named once (paper §3–§5).
register_policy(Policy("fd-basic", "fd", strategy="basic", dynamic=False))
register_policy(Policy("fd-st1", "fd", strategy="st1", dynamic=False))
register_policy(Policy("fd-st1+2", "fd", strategy="st1+2", dynamic=False))
register_policy(Policy("fd-dynamic", "fd", strategy="st1+2", dynamic=True))
register_policy(Policy("cn", "cn"))
register_policy(Policy("cn-star", "cn_star"))
register_policy(Policy("fd-stats", "fd-stats", z=0.8))


def policy_from_legacy(algorithm: str = "fd", strategy: str = "st1+2",
                       dynamic: bool = True,
                       lifetime_mean_s: float = math.inf) -> Policy:
    """Map the legacy ``run_query``/``run_queries`` kwargs to a policy.

    Combinations matching a registered policy resolve to it by name;
    anything else gets an anonymous policy carrying the same knobs.
    """
    for pol in _REGISTRY.values():
        if pol.algorithm != algorithm or pol.algorithm == "fd-stats":
            continue
        if algorithm in ("cn", "cn_star") or (
                pol.strategy == strategy and pol.dynamic == dynamic):
            base = pol
            break
    else:
        tag = "dynamic" if dynamic else "static"
        base = Policy(f"{algorithm}[{strategy},{tag}]", algorithm,
                      strategy=strategy, dynamic=dynamic)
    if not math.isinf(lifetime_mean_s):
        base = base.variant(lifetime_mean_s=lifetime_mean_s)
    return base


@dataclasses.dataclass
class TopKResult:
    """What every backend returns.

    The sim backend fills ``metrics`` (per-entry ``BatchMetrics``); the
    device backend fills ``values`` / ``indices`` (and ``rows`` on the
    data-retrieval gather path).  ``extras`` carries backend specifics:
    fd-stats round metrics, the device comm-model bytes, ...

    ``backend`` names the engine the caller constructed;
    ``backend_used`` records the path that actually executed (defaults
    to ``backend``).  They differ only when an engine falls back — e.g.
    ``fd-stats`` on ``SimEngine(backend="jax")`` runs the numpy
    reference rounds — so tests can assert no SILENT fallback:
    ``assert res.backend_used == res.backend``.

    ``topology`` / ``latency_model`` record WHAT overlay the result was
    measured on (the topology family's registered ``kind`` and the
    effective link-latency regime) — the sim backends fill them, the
    device backend has no overlay and leaves them ``None``.

    ``precision`` records the arithmetic the executed sweep ran in:
    ``"f64"`` results are bit-exact vs the scalar reference; ``"f32"``
    / ``"bf16"`` results are tolerance-checked instead, and
    ``extras["tolerance"]`` carries the measured contract (top-k
    recall + score rtol vs the f64 sweep) when the caller requested
    validation.

    Serving metadata (every backend fills these; the serving layer in
    ``repro.engine.serve`` aggregates them into its per-request
    timings):

    * ``queue_s`` — seconds the request waited before execution began.
      Backends set 0.0 (a direct ``run`` never queues); the
      ``QueryServer`` dispatcher overwrites it with the measured
      enqueue-to-dispatch wait.
    * ``compile_s`` — seconds of plan / trace preparation attributable
      to this call: origin-statics compilation on the sim backends
      (0.0 on a warm ``NetworkPlan``), jitted-callable construction on
      the device backend.
    * ``run_s`` — wall seconds of the executed sweep itself (on the
      jax backends this includes XLA tracing on the first call for a
      given tree profile; warm calls are pure execution).
    * ``batch_size`` — how many requests shared the executed sweep: 1
      for a direct ``run``, the coalesced group size when
      ``Engine.run_many`` (or the server's dynamic batcher) fused this
      request with others.  Fused requests report the SAME
      ``compile_s`` / ``run_s`` (the one sweep they shared).
    """

    policy: str
    backend: str                       # "sim" | "sim-jax" | "device"
    k: int
    backend_used: Optional[str] = None
    topology: Optional[str] = None     # overlay family (sim backends)
    latency_model: Optional[str] = None  # "iid" | "edge" (sim backends)
    precision: str = "f64"             # arithmetic the sweep ran in
    metrics: Optional[BatchMetrics] = None
    values: Any = None
    indices: Any = None
    rows: Any = None
    queue_s: float = 0.0               # wait before execution (server)
    compile_s: float = 0.0             # plan/trace prep for this call
    run_s: float = 0.0                 # executed-sweep wall seconds
    batch_size: int = 1                # requests sharing the sweep
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        """Default ``backend_used`` to the constructed backend."""
        if self.backend_used is None:
            self.backend_used = self.backend

    def query_metrics(self, q: int = 0, t: int = 0) -> QueryMetrics:
        """Scalar per-query metrics (sim backend only)."""
        if self.metrics is None:
            raise ValueError(
                f"the {self.backend!r} backend has no per-query metrics")
        return self.metrics.query_metrics(q, t)

    def summary(self) -> dict:
        """Flat scalar summary: identity fields + metric means +
        scalar extras."""
        out = {"policy": self.policy, "backend": self.backend, "k": self.k}
        if self.topology is not None:
            out["topology"] = self.topology
        if self.latency_model is not None:
            out["latency_model"] = self.latency_model
        if self.metrics is not None:
            out.update(self.metrics.summary())
        out.update({key: v for key, v in self.extras.items()
                    if isinstance(v, (int, float, str, bool))})
        return out


PolicyLike = Union[str, Policy]


class Engine(abc.ABC):
    """The backend contract every engine implements.

    An engine is a LONG-LIVED object: it owns compiled per-overlay /
    per-mesh state (``NetworkPlan``, jit traces, compiled collectives)
    and amortizes it across calls.  Two entrypoints:

    * ``run(spec, policy)`` — one ``QuerySpec``, one ``TopKResult``;
    * ``run_many(specs, policies)`` — a request batch.  Backends group
      COMPATIBLE specs (same policy and effective execution signature)
      onto one batched sweep and split the results back out, so ``N``
      concurrent requests cost one sweep instead of ``N`` — this is
      the call the serving layer's dynamic batcher makes.  Results are
      positionally matched to ``specs`` and each is entry-wise
      bit-exact with what a sequential ``run`` would have returned.

    The base-class ``run_many`` is the trivially correct sequential
    fallback; ``SimEngine`` / ``DeviceEngine`` override it with real
    coalescing.
    """

    #: engine identity recorded on every TopKResult ("sim" | "sim-jax"
    #: | "device"); subclasses overwrite it per instance
    backend = "abstract"

    @abc.abstractmethod
    def run(self, spec: Optional[QuerySpec] = None,
            policy: PolicyLike = "fd-dynamic", **kwargs) -> TopKResult:
        """Execute one ``QuerySpec`` under ``policy``."""

    def run_many(self, specs: Sequence[QuerySpec],
                 policies: Union[PolicyLike, Sequence[PolicyLike]]
                 = "fd-dynamic", **kwargs) -> List[TopKResult]:
        """Execute a batch of specs; result ``i`` answers ``specs[i]``.

        ``policies`` is one policy applied to every spec or a sequence
        zipped with ``specs``.  This default implementation runs the
        specs sequentially — correct for any backend, no coalescing.
        """
        pols = self._zip_policies(specs, policies)
        return [self.run(s, p, **kwargs) for s, p in zip(specs, pols)]

    @staticmethod
    def _zip_policies(specs: Sequence[QuerySpec],
                      policies) -> List[Policy]:
        """Resolve ``policies`` into one ``Policy`` per spec."""
        if isinstance(policies, (str, Policy)):
            return [get_policy(policies)] * len(specs)
        pols = [get_policy(p) for p in policies]
        if len(pols) != len(specs):
            raise ValueError(f"got {len(specs)} specs but {len(pols)} "
                             "policies")
        return pols
