"""NetworkPlan — compiled, cached preprocessing of one overlay topology.

Everything about a topology that does not depend on the trial RNG is
computed once and persists across ``SimEngine.run`` calls:

  * the CSR adjacency and directed edge arrays (+ sorted membership
    keys for the Strategy-2 edge test);
  * per-origin BFS trees, tree levels, children CSR, and forward-phase
    static edge masks (``_OriginStatic``), keyed by (origin, ttl,
    forward strategy);
  * resolved auto-TTL eccentricities (the ``ttl=0`` case), so repeated
    queries never re-run the full-depth BFS.

Repeated queries on the same overlay therefore skip all graph
preprocessing — the warm-vs-cold gap is measured by the ``plan_cache``
suite in ``benchmarks/multi_query.py``.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.p2psim.graph import (Topology, as_csr, bfs_tree_csr,
                                bfs_tree_csr_multi, directed_edges)
from repro.p2psim.simulate import _OriginStatic


class NetworkPlan:
    """Reusable per-topology state shared by every query on an overlay."""

    def __init__(self, top: Topology):
        self.top = top
        self.indptr, self.indices = as_csr(top)
        self.e_src, self.e_dst = directed_edges(self.indptr, self.indices)
        self.edge_keys = self.e_src * top.n + self.e_dst  # sorted by constr.
        self.degrees = np.diff(self.indptr)
        self._statics: Dict[Tuple[int, int, str], _OriginStatic] = {}
        self._auto_ttl: Dict[int, int] = {}

    def auto_ttl(self, origin: int) -> int:
        """Resolved auto-TTL (BFS eccentricity), computed once per origin
        and reused by every later query with ``ttl=0``."""
        o = int(origin)
        if o not in self._auto_ttl:
            _, depth, _ = bfs_tree_csr(self.indptr, self.indices, o,
                                       self.top.n)
            self._auto_ttl[o] = int(depth.max())
        return self._auto_ttl[o]

    def origin_statics(self, origins: np.ndarray, ttl: int,
                       fw_strategy: str):
        """(sts, st_of_q): the unique ``_OriginStatic`` per distinct
        origin (first-appearance order) and the per-query index into it.

        Statics missing from the cache are built with one multi-origin
        BFS sweep; everything already cached is reused as-is.
        """
        uniq: Dict[int, int] = {}
        st_of_q = np.empty(len(origins), np.int64)
        for qi, origin in enumerate(origins):
            key = int(origin)
            if key not in uniq:
                uniq[key] = len(uniq)
            st_of_q[qi] = uniq[key]
        uniq_origins: List[int] = sorted(uniq, key=uniq.get)
        missing = [o for o in uniq_origins
                   if (o, ttl, fw_strategy) not in self._statics]
        if missing:
            P_all, D_all, R_all = bfs_tree_csr_multi(
                self.indptr, self.indices, np.asarray(missing, np.int64),
                self.top.n if ttl == 0 else ttl)
            for i, o in enumerate(missing):
                st = _OriginStatic(self.top, self.indptr, self.indices,
                                   self.e_src, self.e_dst, self.edge_keys,
                                   self.degrees, o, ttl, fw_strategy,
                                   bfs=(P_all[i], D_all[i], R_all[i]))
                self._statics[(o, ttl, fw_strategy)] = st
                if ttl == 0:
                    # the full-depth BFS doubles as the TTL resolution
                    self._auto_ttl.setdefault(o, st.ttl)
        sts = [self._statics[(o, ttl, fw_strategy)] for o in uniq_origins]
        return sts, st_of_q

    def cache_info(self) -> dict:
        return {"origin_statics": len(self._statics),
                "auto_ttls": len(self._auto_ttl)}
