"""NetworkPlan — compiled, cached preprocessing of one overlay topology.

Everything about a topology that does not depend on the trial RNG is
computed once and persists across ``SimEngine.run`` calls:

  * the CSR adjacency and directed edge arrays (+ sorted membership
    keys for the Strategy-2 edge test);
  * the per-edge latency array aligned with the CSR (``edge_lat``,
    coordinate-carrying topologies only) — the deterministic half of
    the ``latency_model="edge"`` link model, gathered per origin into
    ``_OriginStatic.par_lat`` and from there per depth level by both
    sweep backends (it rides inside the ``up_term`` / ``dn_term``
    arrays the shared RNG precompute emits);
  * per-origin BFS trees, tree levels, children CSR, and forward-phase
    static edge masks (``_OriginStatic``), keyed by (origin, ttl,
    forward strategy);
  * resolved auto-TTL eccentricities (the ``ttl=0`` case), so repeated
    queries never re-run the full-depth BFS;
  * the replication placement table (``replica_table``), keyed by
    (factor, placement) and invalidated on overlay mutation.

Repeated queries on the same overlay therefore skip all graph
preprocessing — the warm-vs-cold gap is measured by the ``plan_cache``
suite in ``benchmarks/multi_query.py``.

Plans are NOT frozen: a plan built from a live
:class:`~repro.p2psim.overlay.Overlay` follows its mutations through
:meth:`NetworkPlan.sync`, which patches the per-topology tier in place
and re-validates every cached per-origin tier against a fresh BFS —
keeping whatever the mutation provably did not touch (statics whose
tree is bit-identical; ``DepthSlices`` levels whose compile inputs are
unchanged) and rebuilding only the rest.  The result is bit-exact with
a from-scratch ``NetworkPlan`` of the mutated topology (asserted by
tests/test_overlay.py and the ``overlay_dynamics`` benchmark suite);
see docs/OVERLAY.md for the invalidation tiers.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from repro.p2psim.graph import (Topology, as_csr, bfs_tree_csr,
                                bfs_tree_csr_multi, directed_edges)
from repro.p2psim.overlay import Overlay
from repro.p2psim.simulate import (SimParams, _OriginStatic,
                                   build_replica_table)

_I32_MAX = np.iinfo(np.int32).max


def resolve_index_dtype(n: int, nnz: int, requested: str) -> np.dtype:
    """Pick (and guard) the plan's index width.

    ``requested="int32"`` raises — a clear error instead of a silent
    wrap — whenever any indexable quantity exceeds int32: the peer
    count ``n``, the directed-edge count ``nnz`` (CSR offsets run to
    nnz), or the *virtual edge space* ``n²`` that a packed int32 edge
    key would need (the plan keeps packed keys int64 precisely so the
    common case n > 46340, n² > 2³¹ stays safe — see
    ``NetworkPlan._compile_topology``).  ``"auto"`` falls back to int64
    in those cases instead of raising.
    """
    wide = int(n) > _I32_MAX or int(nnz) > _I32_MAX
    if requested == "int64":
        return np.dtype(np.int64)
    if requested == "int32":
        if wide:
            raise ValueError(
                f"index_dtype='int32' cannot address this plan: "
                f"n={n}, directed edges={nnz} (virtual edge space "
                f"n**2={int(n) * int(n)}) exceed int32's {_I32_MAX}; "
                "use index_dtype='int64' (or 'auto')")
        return np.dtype(np.int32)
    return np.dtype(np.int64 if wide else np.int32)


class DepthSlices:
    """Depth-bucketed dense slices + static merge schedule of one tree.

    Everything the jitted JAX sweep (``repro.engine.sim_jax``) needs to
    run one origin's simulation as pure gathers/concats — no scatters,
    no data-dependent shapes.  Each BFS level is one dense slice; the
    bottom-up k-list merge is precompiled here into a static *fold
    schedule*: per round, which child-slot pairs merge (``mi_a`` /
    ``mi_b``), which odd slots carry over (``pi``), and where each
    parent's finished segment retires (``ret``).  Only real pairwise
    merges are ever executed on device, so the sweep's work is
    O(reached + children) k-list merges regardless of degree skew.

    The jit cache keys on the level/round size profile of the tree (and
    k) — shared across origins whose trees bucket identically and
    reused verbatim across every ``run`` on the same plan — rather than
    on raw per-origin node identities, which travel as device-resident
    index arrays.

    Per depth ``d`` (all indices are *positions*, not node ids):
      * ``vv`` — the level's nodes (ascending);
      * ``par_pos`` — each node's parent position inside level d-1;
      * ``cnode`` — the level's children (= the d+1 reach set) grouped
        by parent; ``c_in_next`` their positions inside level d+1;
        ``cpar_pos`` their parents' positions inside this level;
      * ``par_sel`` / ``leaf_sel`` / ``asm_perm`` — the with-children /
        leaf split of the level and the permutation reassembling
        [parents, leaves] into node order;
      * ``rounds`` / ``ret`` / ``ret_perm`` — the fold schedule.

    With ``reroute=True`` (the churn sweep's §4.2 dead-parent rerouting)
    each level that has grandchildren additionally carries a STATIC
    reroute candidate table: every level-d+2 node is a potential urgent
    contributor to its grandparent at level d whenever its own parent
    died, so the fold schedule is recompiled over the augmented slot set
    [children..., grandchildren...]:

      * ``rr_gc_pos`` — grandchild positions inside level d+2;
      * ``rr_gc_par_pos`` — their parents' positions inside level d+1
        (the liveness gather: a grandchild slot is live iff that parent
        is DEAD);
      * ``rr_rounds`` / ``rr_ret`` / ``rr_ret_perm`` — the augmented
        fold schedule (grandchild slots segment to their grandparent).

    Which slots actually contribute is decided per entry by validity
    masks at run time — the shapes, gathers, and merge schedule stay
    fixed, so rerouting never leaves XLA.
    """

    def __init__(self, st: _OriginStatic, n: int, reroute: bool = False,
                 reuse: Optional[Tuple["DepthSlices",
                                       _OriginStatic]] = None,
                 index_dtype=np.int64):
        """Compile ``st``'s tree into dense slices + fold schedules.

        ``reuse=(old_slices, old_static)`` — incremental-update path:
        levels whose compile inputs are unchanged between ``old_static``
        and ``st`` adopt ``old_slices``' level dicts wholesale instead
        of recompiling (the pure-Python fold schedule dominates the
        cost of a full compile, so reusing untouched levels is what
        makes ``NetworkPlan.sync`` fast; see :meth:`_reusable_levels`).

        ``index_dtype``: dtype of every position/index array (``vv``,
        gathers, fold-schedule slots, els).  int32 halves the plan's
        resident footprint and the device transfer at large n; the
        plan layer picks it only after its overflow guards pass.
        """
        self.n = n
        self.origin = st.origin
        self.reroute = False
        self.dmax = len(st.levels) - 1
        self.index_dtype = np.dtype(index_dtype)
        ix = self._ix
        usable = self._reusable_levels(st, reuse)
        self.levels = []
        for d in range(self.dmax + 1):
            if usable is not None and usable[d]:
                self.levels.append(reuse[0].levels[d])
                continue
            vs = st.levels[d]
            L = len(vs)
            lv = {"vv": ix(vs)}
            if d > 0:
                lv["par_pos"] = ix(np.searchsorted(st.levels[d - 1],
                                                   st.parent[vs]))
            if d < self.dmax:
                ch = st.levels[d + 1]
                order = np.argsort(st.parent[ch], kind="stable")
                cnode = ch[order]
                cpar = st.parent[ch][order]
                lv["cnode"] = ix(cnode)
                lv["c_in_next"] = ix(np.searchsorted(ch, cnode))
                lv["cpar_pos"] = ix(np.searchsorted(vs, cpar))
                par_nodes = np.unique(cpar)          # ascending
                n_par = len(par_nodes)
                par_sel = np.searchsorted(vs, par_nodes)
                leaf_sel = np.setdiff1d(np.arange(L), par_sel)
                lv["par_sel"], lv["leaf_sel"] = ix(par_sel), ix(leaf_sel)
                lv["asm_perm"] = ix(np.argsort(
                    np.concatenate([par_sel, leaf_sel])))
                rounds, ret, segs = self._fold_schedule(
                    np.searchsorted(par_nodes, cpar))
                lv["rounds"] = self._ix_rounds(rounds)
                lv["ret"] = self._ix_ret(ret)
                # concat-of-retirements order -> parent-ascending order
                lv["ret_perm"] = ix(np.argsort(segs, kind="stable"))
            self.levels.append(lv)
        self._set_els(st)
        if reroute:
            self.extend_reroute(st)

    def _ix(self, a: np.ndarray) -> np.ndarray:
        return a.astype(self.index_dtype, copy=False)

    def _ix_rounds(self, rounds):
        return tuple(tuple(self._ix(a) for a in rnd) for rnd in rounds)

    def _ix_ret(self, ret):
        return tuple(None if idx is None else self._ix(idx)
                     for idx in ret)

    def _set_els(self, st: _OriginStatic) -> None:
        """Adopt ``st``'s forward-phase edge masks (Strategy-1/2 els)."""
        if st.fw_strategy == "basic":
            self.n_els = 0
            self.els_src = self.els_dst = np.zeros(0, self.index_dtype)
            self.cond = np.zeros(0, bool)
        else:
            self.n_els = len(st.fw_els_src)
            self.els_src = self._ix(st.fw_els_src)
            self.els_dst = self._ix(st.fw_els_dst)
            self.cond = st.fw_cond

    def _reusable_levels(self, st: _OriginStatic, reuse):
        """Per-level reuse mask for the incremental-update path.

        Level ``d``'s compiled dict is a pure function of the level
        arrays ``levels[d-1..d+1]`` and the parents over them; the
        lazily-extended reroute tables additionally read level ``d+2``
        (grandchildren re-segmented by grandparent).  A level is
        therefore adopted wholesale iff every level in the ``[d-1,
        d+2]`` window is bit-identical (same nodes, same parents)
        between the old and new static — conservative by one level for
        slices that never extend reroute, and exact for those that do.
        """
        if reuse is None:
            return None
        old_sl, old_st = reuse
        odmax = old_sl.dmax

        def eq(d):
            if d > self.dmax and d > odmax:
                return True                    # absent on both sides
            if d > self.dmax or d > odmax:
                return False
            a, b = st.levels[d], old_st.levels[d]
            return bool(np.array_equal(a, b)
                        and np.array_equal(st.parent[a], old_st.parent[b]))

        eqs = [eq(d) for d in range(max(self.dmax, odmax) + 3)]
        return [(d <= odmax and (d < self.dmax) == (d < odmax)
                 and all(eqs[x] for x in range(max(0, d - 1), d + 3)))
                for d in range(self.dmax + 1)]

    def refresh(self, st: _OriginStatic) -> None:
        """Incremental-update path for a patched ``st`` whose TREE is
        unchanged: only the edge-derived forward masks can differ, so
        re-adopt them and drop the device caches (level dicts — and any
        reroute tables, which depend on the tree alone — stay)."""
        self._set_els(st)
        for a in ("_device", "_device_rr"):
            if hasattr(self, a):
                delattr(self, a)

    def extend_reroute(self, st: _OriginStatic) -> None:
        """Add the reroute tables to THIS instance, in place.

        Level d's grandchildren are level d+1's children, re-segmented
        by grandparent (always one of level d's parents: a grandchild's
        grandparent has the dead child as a child by construction).
        Everything already compiled is shared — the churn sweep extends
        the cached slices instead of duplicating them, and the base
        device arrays (plus any jitted static-sweep traces over them)
        stay valid: the rr tables travel as a SEPARATE device-cached
        pytree (see ``sim_jax._device_slices``).
        """
        if self.reroute:
            return
        for d in range(self.dmax - 1):
            lv, nxt = self.levels[d], self.levels[d + 1]
            if "rr_rounds" in lv:
                continue            # adopted by the incremental path
            par_nodes = lv["vv"][lv["par_sel"]]
            gp = st.parent[st.parent[nxt["cnode"]]]
            lv["rr_gc_pos"] = nxt["c_in_next"]
            lv["rr_gc_par_pos"] = nxt["cpar_pos"]
            seg = np.concatenate([
                np.searchsorted(par_nodes, st.parent[lv["cnode"]]),
                np.searchsorted(par_nodes, gp)])
            rounds, ret, segs = self._fold_schedule(seg)
            lv["rr_rounds"] = self._ix_rounds(rounds)
            lv["rr_ret"] = self._ix_ret(ret)
            lv["rr_ret_perm"] = self._ix(np.argsort(segs, kind="stable"))
        self.reroute = True

    @staticmethod
    def _fold_schedule(seg_of_slot: np.ndarray):
        """Static schedule of the segmented pairwise top-k reduction.

        Returns (rounds, ret, segs): ``rounds[r] = (mi_a, mi_b, pi)``
        index arrays into round r's input array (round 0's input is the
        parent-grouped child-list array) — pairs to merge plus odd
        slots carried over, output layout [merged..., carried...];
        ``ret[r]`` — the slots of round r's array holding a finished
        segment's full reduction (None when no segment finishes there;
        round 0 retires single-child parents); ``segs`` — the segment
        ids in concat-of-retirements order.
        """
        slots: dict = {}
        for i, seg in enumerate(seg_of_slot):
            slots.setdefault(int(seg), []).append(i)
        rounds, ret, seg_order = [], [], []
        while True:
            done = [(v[0], s) for s, v in sorted(slots.items())
                    if len(v) == 1]
            ret.append(np.array([i for i, _ in done])
                       if done else None)
            seg_order.extend(s for _, s in done)
            slots = {s: v for s, v in slots.items() if len(v) > 1}
            if not slots:
                break
            mi_a, mi_b, pi = [], [], []
            nxt: dict = {}
            for s in sorted(slots):
                v = slots[s]
                for j in range(0, len(v) - 1, 2):
                    nxt.setdefault(s, []).append(len(mi_a))
                    mi_a.append(v[j])
                    mi_b.append(v[j + 1])
                if len(v) % 2:
                    pi.append(v[-1])
            off = len(mi_a)
            for j, s in enumerate(s for s in sorted(slots)
                                  if len(slots[s]) % 2):
                nxt[s].append(off + j)
            rounds.append((np.array(mi_a), np.array(mi_b),
                           np.array(pi, np.int64)))
            slots = nxt
        return (tuple(rounds), tuple(ret),
                np.array(seg_order, np.int64))


def _edge_delta(deltas):
    """Net undirected (removed, added) edge sets from an overlay
    journal slice — add/remove pairs that cancel out drop away, so the
    per-origin patch only sees edges whose existence actually
    changed."""
    net: Dict[Tuple[int, int], int] = {}

    def bump(a, b, s):
        k = (a, b) if a < b else (b, a)
        net[k] = net.get(k, 0) + s

    for d in deltas:
        if d.op == "add_edge":
            bump(d.nodes[0], d.nodes[1], 1)
        elif d.op == "remove_edge":
            bump(d.nodes[0], d.nodes[1], -1)
        elif d.op == "remove_peer":
            for f in d.nodes[1:]:
                bump(d.nodes[0], f, -1)
    removed = [k for k, s in net.items() if s < 0]
    added = [k for k, s in net.items() if s > 0]
    return removed, added


_PATCH_MAX_OPS = 12     # journal size beyond which sync just re-sweeps


class _Bail(Exception):
    """Internal: a tree-patch rule hit a structural case — re-sweep."""


def _patch_tree(st, deltas, n: int, limit: int, indptr, indices):
    """BFS-free (parent, depth, reached, rank) after a SMALL delta.

    Replays the overlay journal against one cached tree using the
    stored within-level discovery ranks as a first-touch certificate:
    same-depth claim priority is exactly rank order, so single joins,
    leaves, and rewires resolve without re-running the sweep.  The
    result is bit-identical to a fresh ``bfs_tree_csr`` on the patched
    CSR.  Returns None — caller falls back to the multi-origin BFS —
    for anything structural: an orphaned subtree, a shortcut through a
    node with tree children, a claim cascade, an unreached region
    becoming reachable, or a journal longer than ``_PATCH_MAX_OPS``.

    Soundness rests on two facts about the first-touch flood: (1)
    deleting or inserting candidate slots shifts all later slot
    positions monotonically, so the RELATIVE claim order of untouched
    nodes never changes; (2) a level's claim order is lexicographic in
    (parent's rank, child id) because adjacency is kept sorted — which
    makes the stored ranks a total order any new claim can be placed
    into fractionally.
    """
    if len(deltas) > _PATCH_MAX_OPS or st.rank is None:
        return None
    old_n = len(st.parent)
    if old_n == n:
        P, D, K = st.parent.copy(), st.depth.copy(), st.rank.copy()
    else:
        P = np.concatenate([st.parent, np.full(n - old_n, -1, np.int64)])
        D = np.concatenate([st.depth, np.full(n - old_n, -1, np.int64)])
        K = np.concatenate([st.rank, np.full(n - old_n, -1.0)])
    ops = [(d.op, d.nodes) for d in deltas]
    touched: set = set()
    relevels: set = set()   # levels whose membership changed: renumber

    def neighbors_at(z: int, i: int) -> set:
        """z's neighbor set just AFTER journal op i (final CSR with the
        not-yet-applied ops undone)."""
        nb = set(int(y) for y in indices[indptr[z]:indptr[z + 1]])
        for op, nodes in ops[i + 1:][::-1]:
            if op == "add_edge" and z in nodes[:2]:
                nb.discard(nodes[0] if z == nodes[1] else nodes[1])
            elif op == "remove_edge" and z in nodes[:2]:
                nb.add(nodes[0] if z == nodes[1] else nodes[1])
            elif op == "remove_peer":
                if z == nodes[0]:
                    nb.update(nodes[1:])
                elif z in nodes[1:]:
                    nb.add(nodes[0])
        return nb

    def childless(v: int) -> bool:
        return not np.any(P == v)

    def level_members(d: int, but: int):
        """Current level-d nodes except ``but`` (old level array filtered
        by the live depth, plus any nodes moved in by earlier rules)."""
        base = (st.levels[d] if d < len(st.levels)
                else np.zeros(0, np.int64))
        base = base[(D[base] == d) & (base != but)]
        extra = [t for t in touched
                 if D[t] == d and t != but
                 and (d >= len(st.levels)
                      or not _in_sorted(st.levels[d], t))]
        if extra:
            base = np.concatenate([base, np.asarray(extra, np.int64)])
        return base

    def rank_between(u: int, w: int, d: int) -> float:
        """A rank for w claimed by u at depth d, strictly between its
        lexicographic (parent rank, id) neighbors in the level."""
        m = level_members(d, w)
        if not len(m):
            return 0.0
        kp = K[P[m]]
        lower = (kp < K[u]) | ((kp == K[u]) & (m < w))
        lo = K[m][lower].max() if lower.any() else None
        hi = K[m][~lower].min() if not lower.all() else None
        if lo is None:
            return float(hi) - 1.0
        if hi is None:
            return float(lo) + 1.0
        return (float(lo) + float(hi)) / 2.0

    def claims_ok(w: int, dn: int, kw: float, i: int) -> None:
        """Bail unless w, (re)claimed at depth dn with rank kw, provably
        claims nothing itself in the fresh flood."""
        if dn >= limit:
            return                        # w is never expanded
        for y in neighbors_at(w, i):
            if D[y] < 0:
                raise _Bail               # w would reach a new region
            if D[y] > dn + 1:
                raise _Bail               # shortcut through w
            if D[y] == dn + 1 and kw < K[P[y]]:
                raise _Bail               # w would steal y's claim

    def move(w: int, dn: int, u: int, i: int) -> None:
        """Re-attach childless w as u's child at depth dn."""
        kw = rank_between(u, w, dn)
        claims_ok(w, dn, kw, i)
        if D[w] >= 0:
            relevels.add(int(D[w]))
        relevels.add(int(dn))
        P[w], D[w], K[w] = u, dn, kw
        touched.add(w)

    try:
        for i, (op, nodes) in enumerate(ops):
            if op == "add_peer":
                continue                  # link-less: unreached
            if op == "remove_peer":
                v = nodes[0]
                if D[v] >= 0:
                    if not childless(v):
                        raise _Bail       # orphaned subtree
                    relevels.add(int(D[v]))
                    P[v], D[v], K[v] = -1, -1, -1.0
                    touched.add(v)
                continue
            if op == "remove_edge":
                u, w = int(nodes[0]), int(nodes[1])
                for a, b in ((u, w), (w, u)):
                    if P[b] != a:
                        continue          # non-tree side: claim slots
                    if not childless(b):  # only shift, order preserved
                        raise _Bail
                    cand = [y for y in neighbors_at(b, i)
                            if D[y] >= 0 and D[y] < limit]
                    if not cand:          # b falls out of reach
                        relevels.add(int(D[b]))
                        P[b], D[b], K[b] = -1, -1, -1.0
                        touched.add(b)
                        continue
                    dn = min(D[y] for y in cand) + 1
                    par = min((y for y in cand if D[y] == dn - 1),
                              key=lambda y: K[y])
                    move(b, dn, par, i)
                continue
            if op == "add_edge":
                u, w = int(nodes[0]), int(nodes[1])
                if D[u] < 0 and D[w] < 0:
                    continue              # invisible to this tree
                if D[u] < 0 or D[w] < 0:
                    b, a = (u, w) if D[u] < 0 else (w, u)
                    if D[a] >= limit:
                        continue          # beyond the horizon
                    if not childless(b):
                        raise _Bail
                    move(b, D[a] + 1, a, i)
                    continue
                if D[u] == D[w]:
                    continue              # same level never claims
                a, b = (u, w) if D[u] < D[w] else (w, u)
                if D[b] == D[a] + 1:
                    if K[a] < K[P[b]]:    # a's claim slot comes first
                        if not childless(b):
                            raise _Bail
                        move(b, D[b], a, i)
                    continue              # else b was claimed earlier
                if D[a] >= limit:
                    continue
                if not childless(b):
                    raise _Bail           # shortcut through b's subtree
                move(b, D[a] + 1, a, i)
                continue
            raise _Bail                   # unknown journal op
    except _Bail:
        return None
    # canonicalise: fractional insertions and removal gaps are only
    # order-isomorphic to a fresh flood's ranks — renumber every level
    # whose membership changed so the result is bit-identical
    for d in relevels:
        m = level_members(d, -1)
        if len(m):
            K[m[np.argsort(K[m], kind="stable")]] = np.arange(
                len(m), dtype=np.float64)
    return P, D, D >= 0, K


def _in_sorted(arr, x) -> bool:
    p = int(np.searchsorted(arr, x))
    return p < len(arr) and arr[p] == x


class NetworkPlan:
    """Reusable per-topology state shared by every query on an overlay.

    Accepts a frozen :class:`Topology` or a live
    :class:`~repro.p2psim.overlay.Overlay`; in the latter case the plan
    records the overlay version it was compiled at and
    :meth:`sync` (called by the engines before every execution) patches
    the caches incrementally whenever the overlay has moved on.
    """

    def __init__(self, top: Union[Topology, Overlay], *,
                 index_dtype: str = "auto"):
        """Compile the per-topology state (CSR, edges, latency array).

        ``index_dtype``: width of the CSR / edge / depth-slice index
        arrays — ``"int64"`` (the historical default width),
        ``"int32"`` (halves the index footprint and device transfer;
        guarded — raises if the plan cannot be addressed in 32 bits),
        or ``"auto"`` (int32 whenever the guards pass).  The packed
        ``edge_keys`` stay int64 regardless: their value space is n²,
        which silently wraps int32 from n = 46341 up.
        """
        if index_dtype not in ("auto", "int32", "int64"):
            raise ValueError(
                "index_dtype must be 'auto', 'int32' or 'int64', got "
                f"{index_dtype!r}")
        self._index_dtype_req = index_dtype
        self.overlay: Optional[Overlay] = None
        if isinstance(top, Overlay):
            self.overlay = top
            top = top.top
        self.top = top
        self._compile_topology()
        self._statics: Dict[Tuple[int, int, str], _OriginStatic] = {}
        self._auto_ttl: Dict[int, int] = {}
        self._slices: Dict[Tuple[int, int, str], DepthSlices] = {}
        self._replicas: Dict[Tuple[int, str], np.ndarray] = {}
        self.version = self.overlay.version if self.overlay else 0

    def _compile_topology(self) -> None:
        """(Re)compile the per-topology tier from ``self.top``."""
        top = self.top
        self.indptr, self.indices = as_csr(top)
        dt = resolve_index_dtype(top.n, len(self.indices),
                                 self._index_dtype_req)
        self.index_dtype = dt
        self.indptr = self.indptr.astype(dt, copy=False)
        self.indices = self.indices.astype(dt, copy=False)
        self.e_src, self.e_dst = directed_edges(self.indptr, self.indices)
        self.e_src = self.e_src.astype(dt, copy=False)
        self.e_dst = self.e_dst.astype(dt, copy=False)
        # packed (src, dst) keys: the value space is n*n — ALWAYS int64,
        # an int32 key would silently wrap from n = 46341 up
        self.edge_keys = (self.e_src.astype(np.int64) * top.n
                          + self.e_dst)                # sorted by constr.
        # message-count arithmetic accumulates over degrees: keep wide
        self.degrees = np.diff(self.indptr).astype(np.int64, copy=False)
        # CSR-aligned per-edge latencies (BRITE distance model); None
        # for embeddings-free topologies, which support iid only
        self.edge_lat = (top.edge_latencies(self.e_src, self.e_dst)
                         if top.coords is not None else None)

    # ---- incremental updates (live overlays) ----------------------------

    def sync(self, overlay: Optional[Overlay] = None) -> bool:
        """Bring the plan up to date with its overlay; True if it moved.

        Cheap no-op when the versions already match.  Otherwise the
        per-topology tier is recompiled (vectorized O(E)) and every
        cached per-origin tier is re-validated against a fresh
        multi-origin BFS on the patched CSR:

          * statics whose (parent, depth) came out bit-identical are
            KEPT — only their edge-derived fields (forward masks,
            degree metrics, latency gathers) are re-derived, and their
            ``DepthSlices`` keep every compiled level;
          * changed statics are rebuilt from the already-computed BFS,
            and their ``DepthSlices`` recompile only the levels whose
            inputs differ (see :meth:`DepthSlices._reusable_levels`);
          * auto-TTLs are re-resolved from the same BFS pass
            (``ttl=0`` statics) or dropped for lazy recompute;
          * replication tables are invalidated.

        Bit-exactness vs a from-scratch plan holds by construction:
        the same BFS runs on the same CSR, and anything reused is only
        reused when its compile inputs are bit-identical.
        """
        ov = overlay if overlay is not None else self.overlay
        if ov is None:
            return False
        if self.overlay is None:
            self.overlay = ov
        if ov.top is not self.top:
            raise ValueError(
                "sync() got an overlay wrapping a different Topology "
                "than this plan was compiled from")
        if ov.version == self.version:
            return False
        self._apply_update()
        self.version = ov.version
        return True

    def _apply_update(self) -> None:
        old_n = len(self.indptr) - 1
        old_csr = (old_n, self.indptr, self.indices, self.e_src,
                   self.e_dst, self.edge_keys)
        deltas = self.overlay.deltas_since(self.version)
        removed, added = _edge_delta(deltas)
        self._compile_topology()
        self._replicas.clear()
        n = self.top.n
        if not self._statics:
            self._auto_ttl.clear()
            self._slices.clear()
            return
        # one vectorized BFS sweep per distinct ttl over the cached keys
        by_ttl: Dict[int, List[int]] = {}
        for (o, ttl, _fs) in self._statics:
            lst = by_ttl.setdefault(ttl, [])
            if o not in lst:
                lst.append(o)
        # rank-certified tree patch first (no sweep for single joins /
        # leaves / rewires); the multi-origin BFS only covers origins
        # whose delta was structural
        old_tree: Dict[Tuple[int, int], _OriginStatic] = {}
        for (o, ttl, _fs), st in self._statics.items():
            old_tree.setdefault((o, ttl), st)
        bfs_new = {}
        for ttl, os_ in by_ttl.items():
            limit = n if ttl == 0 else ttl
            need = []
            for o in os_:
                res = _patch_tree(old_tree[(o, ttl)], deltas, n, limit,
                                  self.indptr, self.indices)
                if res is None:
                    need.append(o)
                else:
                    bfs_new[(o, ttl)] = res
            if need:
                P, D, R, K = bfs_tree_csr_multi(
                    self.indptr, self.indices, np.asarray(need, np.int64),
                    limit, return_rank=True)
                for i, o in enumerate(need):
                    bfs_new[(o, ttl)] = (P[i], D[i], R[i], K[i])
        statics, slices, auto_ttl = {}, {}, {}
        for key, st in self._statics.items():
            o, ttl, fs = key
            P, D, R, K = bfs_new[(o, ttl)]
            sl = self._slices.get(key)
            if (old_n == n and np.array_equal(st.parent, P)
                    and np.array_equal(st.depth, D)):
                # tree intact: keep the static, re-derive the
                # edge-dependent fields, keep every compiled level
                st.refresh_edges(self.top, self.e_src, self.e_dst,
                                 self.edge_keys, self.degrees,
                                 self.edge_lat)
                if sl is not None:
                    sl.refresh(st)
            else:
                new_st = _OriginStatic.patched(
                    st, self.top, self.indptr, self.indices, self.e_src,
                    self.e_dst, self.edge_keys, self.degrees, ttl,
                    (P, D, R, K), self.edge_lat, old_csr, removed, added)
                if new_st is None:        # large/structural delta
                    new_st = _OriginStatic(
                        self.top, self.indptr, self.indices, self.e_src,
                        self.e_dst, self.edge_keys, self.degrees, o, ttl,
                        fs, bfs=(P, D, R, K), edge_lat=self.edge_lat)
                if sl is not None:
                    sl = DepthSlices(new_st, n, reroute=sl.reroute,
                                     reuse=(sl, st),
                                     index_dtype=self.index_dtype)
                st = new_st
            statics[key] = st
            if sl is not None:
                slices[key] = sl
            if ttl == 0:
                auto_ttl[o] = st.ttl
        self._statics, self._slices = statics, slices
        self._auto_ttl = auto_ttl   # anything else: lazily re-resolved

    def replica_table(self, p: SimParams) -> Optional[np.ndarray]:
        """The (n, r) replication placement table for ``p`` (cached per
        (factor, placement), invalidated on overlay mutation); None when
        replication is off."""
        r = p.replication_factor
        if r <= 0:
            return None
        key = (r, p.replication_placement)
        tab = self._replicas.get(key)
        if tab is None:
            tab = self._replicas[key] = build_replica_table(
                self.indptr, self.indices, r, p.replication_placement)
        return tab

    def depth_slices(self, st: _OriginStatic,
                     reroute: bool = False) -> DepthSlices:
        """Padded depth-bucketed arrays for ``st`` (the jitted sweep's
        inputs), compiled once per (origin, ttl, strategy) and cached.
        ``reroute=True`` lazily EXTENDS the cached instance with the
        static §4.2 dead-parent reroute tables the churn sweep folds
        over — the base arrays are never duplicated."""
        key = (st.origin, st.ttl, st.fw_strategy)
        sl = self._slices.get(key)
        if sl is None:
            sl = self._slices[key] = DepthSlices(
                st, self.top.n, reroute=reroute,
                index_dtype=self.index_dtype)
        elif reroute:
            sl.extend_reroute(st)
        return sl

    def auto_ttl(self, origin: int) -> int:
        """Resolved auto-TTL (BFS eccentricity), computed once per origin
        and reused by every later query with ``ttl=0``."""
        o = int(origin)
        if o not in self._auto_ttl:
            _, depth, _ = bfs_tree_csr(self.indptr, self.indices, o,
                                       self.top.n)
            self._auto_ttl[o] = int(depth.max())
        return self._auto_ttl[o]

    def origin_statics(self, origins: np.ndarray, ttl: int,
                       fw_strategy: str):
        """(sts, st_of_q): the unique ``_OriginStatic`` per distinct
        origin (first-appearance order) and the per-query index into it.

        Statics missing from the cache are built with one multi-origin
        BFS sweep; everything already cached is reused as-is.
        """
        uniq: Dict[int, int] = {}
        st_of_q = np.empty(len(origins), np.int64)
        for qi, origin in enumerate(origins):
            key = int(origin)
            if key not in uniq:
                uniq[key] = len(uniq)
            st_of_q[qi] = uniq[key]
        uniq_origins: List[int] = sorted(uniq, key=uniq.get)
        missing = [o for o in uniq_origins
                   if (o, ttl, fw_strategy) not in self._statics]
        if missing:
            P_all, D_all, R_all, K_all = bfs_tree_csr_multi(
                self.indptr, self.indices, np.asarray(missing, np.int64),
                self.top.n if ttl == 0 else ttl, return_rank=True)
            for i, o in enumerate(missing):
                st = _OriginStatic(self.top, self.indptr, self.indices,
                                   self.e_src, self.e_dst, self.edge_keys,
                                   self.degrees, o, ttl, fw_strategy,
                                   bfs=(P_all[i], D_all[i], R_all[i],
                                        K_all[i]),
                                   edge_lat=self.edge_lat)
                self._statics[(o, ttl, fw_strategy)] = st
                if ttl == 0:
                    # the full-depth BFS doubles as the TTL resolution
                    self._auto_ttl.setdefault(o, st.ttl)
        sts = [self._statics[(o, ttl, fw_strategy)] for o in uniq_origins]
        return sts, st_of_q

    def cache_info(self) -> dict:
        """Cache-occupancy counters (statics / auto-TTLs / slices)."""
        return {"origin_statics": len(self._statics),
                "auto_ttls": len(self._auto_ttl),
                "depth_slices": len(self._slices)}
