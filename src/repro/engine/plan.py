"""NetworkPlan — compiled, cached preprocessing of one overlay topology.

Everything about a topology that does not depend on the trial RNG is
computed once and persists across ``SimEngine.run`` calls:

  * the CSR adjacency and directed edge arrays (+ sorted membership
    keys for the Strategy-2 edge test);
  * the per-edge latency array aligned with the CSR (``edge_lat``,
    coordinate-carrying topologies only) — the deterministic half of
    the ``latency_model="edge"`` link model, gathered per origin into
    ``_OriginStatic.par_lat`` and from there per depth level by both
    sweep backends (it rides inside the ``up_term`` / ``dn_term``
    arrays the shared RNG precompute emits);
  * per-origin BFS trees, tree levels, children CSR, and forward-phase
    static edge masks (``_OriginStatic``), keyed by (origin, ttl,
    forward strategy);
  * resolved auto-TTL eccentricities (the ``ttl=0`` case), so repeated
    queries never re-run the full-depth BFS.

Repeated queries on the same overlay therefore skip all graph
preprocessing — the warm-vs-cold gap is measured by the ``plan_cache``
suite in ``benchmarks/multi_query.py``.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.p2psim.graph import (Topology, as_csr, bfs_tree_csr,
                                bfs_tree_csr_multi, directed_edges)
from repro.p2psim.simulate import _OriginStatic


class DepthSlices:
    """Depth-bucketed dense slices + static merge schedule of one tree.

    Everything the jitted JAX sweep (``repro.engine.sim_jax``) needs to
    run one origin's simulation as pure gathers/concats — no scatters,
    no data-dependent shapes.  Each BFS level is one dense slice; the
    bottom-up k-list merge is precompiled here into a static *fold
    schedule*: per round, which child-slot pairs merge (``mi_a`` /
    ``mi_b``), which odd slots carry over (``pi``), and where each
    parent's finished segment retires (``ret``).  Only real pairwise
    merges are ever executed on device, so the sweep's work is
    O(reached + children) k-list merges regardless of degree skew.

    The jit cache keys on the level/round size profile of the tree (and
    k) — shared across origins whose trees bucket identically and
    reused verbatim across every ``run`` on the same plan — rather than
    on raw per-origin node identities, which travel as device-resident
    index arrays.

    Per depth ``d`` (all indices are *positions*, not node ids):
      * ``vv`` — the level's nodes (ascending);
      * ``par_pos`` — each node's parent position inside level d-1;
      * ``cnode`` — the level's children (= the d+1 reach set) grouped
        by parent; ``c_in_next`` their positions inside level d+1;
        ``cpar_pos`` their parents' positions inside this level;
      * ``par_sel`` / ``leaf_sel`` / ``asm_perm`` — the with-children /
        leaf split of the level and the permutation reassembling
        [parents, leaves] into node order;
      * ``rounds`` / ``ret`` / ``ret_perm`` — the fold schedule.

    With ``reroute=True`` (the churn sweep's §4.2 dead-parent rerouting)
    each level that has grandchildren additionally carries a STATIC
    reroute candidate table: every level-d+2 node is a potential urgent
    contributor to its grandparent at level d whenever its own parent
    died, so the fold schedule is recompiled over the augmented slot set
    [children..., grandchildren...]:

      * ``rr_gc_pos`` — grandchild positions inside level d+2;
      * ``rr_gc_par_pos`` — their parents' positions inside level d+1
        (the liveness gather: a grandchild slot is live iff that parent
        is DEAD);
      * ``rr_rounds`` / ``rr_ret`` / ``rr_ret_perm`` — the augmented
        fold schedule (grandchild slots segment to their grandparent).

    Which slots actually contribute is decided per entry by validity
    masks at run time — the shapes, gathers, and merge schedule stay
    fixed, so rerouting never leaves XLA.
    """

    def __init__(self, st: _OriginStatic, n: int, reroute: bool = False):
        """Compile ``st``'s tree into dense slices + fold schedules."""
        self.n = n
        self.origin = st.origin
        self.reroute = False
        self.dmax = len(st.levels) - 1
        self.levels = []
        for d in range(self.dmax + 1):
            vs = st.levels[d]
            L = len(vs)
            lv = {"vv": vs.astype(np.int64)}
            if d > 0:
                lv["par_pos"] = np.searchsorted(st.levels[d - 1],
                                                st.parent[vs])
            if d < self.dmax:
                ch = st.levels[d + 1]
                order = np.argsort(st.parent[ch], kind="stable")
                cnode = ch[order]
                cpar = st.parent[ch][order]
                lv["cnode"] = cnode
                lv["c_in_next"] = np.searchsorted(ch, cnode)
                lv["cpar_pos"] = np.searchsorted(vs, cpar)
                par_nodes = np.unique(cpar)          # ascending
                n_par = len(par_nodes)
                par_sel = np.searchsorted(vs, par_nodes)
                leaf_sel = np.setdiff1d(np.arange(L), par_sel)
                lv["par_sel"], lv["leaf_sel"] = par_sel, leaf_sel
                lv["asm_perm"] = np.argsort(
                    np.concatenate([par_sel, leaf_sel]))
                rounds, ret, segs = self._fold_schedule(
                    np.searchsorted(par_nodes, cpar))
                lv["rounds"], lv["ret"] = rounds, ret
                # concat-of-retirements order -> parent-ascending order
                lv["ret_perm"] = np.argsort(segs, kind="stable")
            self.levels.append(lv)
        if st.fw_strategy == "basic":
            self.n_els = 0
            self.els_src = self.els_dst = np.zeros(0, np.int64)
            self.cond = np.zeros(0, bool)
        else:
            self.n_els = len(st.fw_els_src)
            self.els_src = st.fw_els_src
            self.els_dst = st.fw_els_dst
            self.cond = st.fw_cond
        if reroute:
            self.extend_reroute(st)

    def extend_reroute(self, st: _OriginStatic) -> None:
        """Add the reroute tables to THIS instance, in place.

        Level d's grandchildren are level d+1's children, re-segmented
        by grandparent (always one of level d's parents: a grandchild's
        grandparent has the dead child as a child by construction).
        Everything already compiled is shared — the churn sweep extends
        the cached slices instead of duplicating them, and the base
        device arrays (plus any jitted static-sweep traces over them)
        stay valid: the rr tables travel as a SEPARATE device-cached
        pytree (see ``sim_jax._device_slices``).
        """
        if self.reroute:
            return
        for d in range(self.dmax - 1):
            lv, nxt = self.levels[d], self.levels[d + 1]
            par_nodes = lv["vv"][lv["par_sel"]]
            gp = st.parent[st.parent[nxt["cnode"]]]
            lv["rr_gc_pos"] = nxt["c_in_next"]
            lv["rr_gc_par_pos"] = nxt["cpar_pos"]
            seg = np.concatenate([
                np.searchsorted(par_nodes, st.parent[lv["cnode"]]),
                np.searchsorted(par_nodes, gp)])
            rounds, ret, segs = self._fold_schedule(seg)
            lv["rr_rounds"], lv["rr_ret"] = rounds, ret
            lv["rr_ret_perm"] = np.argsort(segs, kind="stable")
        self.reroute = True

    @staticmethod
    def _fold_schedule(seg_of_slot: np.ndarray):
        """Static schedule of the segmented pairwise top-k reduction.

        Returns (rounds, ret, segs): ``rounds[r] = (mi_a, mi_b, pi)``
        index arrays into round r's input array (round 0's input is the
        parent-grouped child-list array) — pairs to merge plus odd
        slots carried over, output layout [merged..., carried...];
        ``ret[r]`` — the slots of round r's array holding a finished
        segment's full reduction (None when no segment finishes there;
        round 0 retires single-child parents); ``segs`` — the segment
        ids in concat-of-retirements order.
        """
        slots: dict = {}
        for i, seg in enumerate(seg_of_slot):
            slots.setdefault(int(seg), []).append(i)
        rounds, ret, seg_order = [], [], []
        while True:
            done = [(v[0], s) for s, v in sorted(slots.items())
                    if len(v) == 1]
            ret.append(np.array([i for i, _ in done])
                       if done else None)
            seg_order.extend(s for _, s in done)
            slots = {s: v for s, v in slots.items() if len(v) > 1}
            if not slots:
                break
            mi_a, mi_b, pi = [], [], []
            nxt: dict = {}
            for s in sorted(slots):
                v = slots[s]
                for j in range(0, len(v) - 1, 2):
                    nxt.setdefault(s, []).append(len(mi_a))
                    mi_a.append(v[j])
                    mi_b.append(v[j + 1])
                if len(v) % 2:
                    pi.append(v[-1])
            off = len(mi_a)
            for j, s in enumerate(s for s in sorted(slots)
                                  if len(slots[s]) % 2):
                nxt[s].append(off + j)
            rounds.append((np.array(mi_a), np.array(mi_b),
                           np.array(pi, np.int64)))
            slots = nxt
        return (tuple(rounds), tuple(ret),
                np.array(seg_order, np.int64))


class NetworkPlan:
    """Reusable per-topology state shared by every query on an overlay."""

    def __init__(self, top: Topology):
        """Compile the per-topology state (CSR, edges, latency array)."""
        self.top = top
        self.indptr, self.indices = as_csr(top)
        self.e_src, self.e_dst = directed_edges(self.indptr, self.indices)
        self.edge_keys = self.e_src * top.n + self.e_dst  # sorted by constr.
        self.degrees = np.diff(self.indptr)
        # CSR-aligned per-edge latencies (BRITE distance model); None
        # for embeddings-free topologies, which support iid only
        self.edge_lat = (top.edge_latencies(self.e_src, self.e_dst)
                         if top.coords is not None else None)
        self._statics: Dict[Tuple[int, int, str], _OriginStatic] = {}
        self._auto_ttl: Dict[int, int] = {}
        self._slices: Dict[Tuple[int, int, str], DepthSlices] = {}

    def depth_slices(self, st: _OriginStatic,
                     reroute: bool = False) -> DepthSlices:
        """Padded depth-bucketed arrays for ``st`` (the jitted sweep's
        inputs), compiled once per (origin, ttl, strategy) and cached.
        ``reroute=True`` lazily EXTENDS the cached instance with the
        static §4.2 dead-parent reroute tables the churn sweep folds
        over — the base arrays are never duplicated."""
        key = (st.origin, st.ttl, st.fw_strategy)
        sl = self._slices.get(key)
        if sl is None:
            sl = self._slices[key] = DepthSlices(st, self.top.n,
                                                 reroute=reroute)
        elif reroute:
            sl.extend_reroute(st)
        return sl

    def auto_ttl(self, origin: int) -> int:
        """Resolved auto-TTL (BFS eccentricity), computed once per origin
        and reused by every later query with ``ttl=0``."""
        o = int(origin)
        if o not in self._auto_ttl:
            _, depth, _ = bfs_tree_csr(self.indptr, self.indices, o,
                                       self.top.n)
            self._auto_ttl[o] = int(depth.max())
        return self._auto_ttl[o]

    def origin_statics(self, origins: np.ndarray, ttl: int,
                       fw_strategy: str):
        """(sts, st_of_q): the unique ``_OriginStatic`` per distinct
        origin (first-appearance order) and the per-query index into it.

        Statics missing from the cache are built with one multi-origin
        BFS sweep; everything already cached is reused as-is.
        """
        uniq: Dict[int, int] = {}
        st_of_q = np.empty(len(origins), np.int64)
        for qi, origin in enumerate(origins):
            key = int(origin)
            if key not in uniq:
                uniq[key] = len(uniq)
            st_of_q[qi] = uniq[key]
        uniq_origins: List[int] = sorted(uniq, key=uniq.get)
        missing = [o for o in uniq_origins
                   if (o, ttl, fw_strategy) not in self._statics]
        if missing:
            P_all, D_all, R_all = bfs_tree_csr_multi(
                self.indptr, self.indices, np.asarray(missing, np.int64),
                self.top.n if ttl == 0 else ttl)
            for i, o in enumerate(missing):
                st = _OriginStatic(self.top, self.indptr, self.indices,
                                   self.e_src, self.e_dst, self.edge_keys,
                                   self.degrees, o, ttl, fw_strategy,
                                   bfs=(P_all[i], D_all[i], R_all[i]),
                                   edge_lat=self.edge_lat)
                self._statics[(o, ttl, fw_strategy)] = st
                if ttl == 0:
                    # the full-depth BFS doubles as the TTL resolution
                    self._auto_ttl.setdefault(o, st.ttl)
        sts = [self._statics[(o, ttl, fw_strategy)] for o in uniq_origins]
        return sts, st_of_q

    def cache_info(self) -> dict:
        """Cache-occupancy counters (statics / auto-TTLs / slices)."""
        return {"origin_statics": len(self._statics),
                "auto_ttls": len(self._auto_ttl),
                "depth_slices": len(self._slices)}
