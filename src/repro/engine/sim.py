"""SimEngine — the unified engine over the vectorized numpy simulator.

``prepare(topology)`` compiles a :class:`~repro.engine.plan.NetworkPlan`
once; every subsequent ``run(spec, policy)`` reuses the cached CSR,
directed edges, per-origin BFS trees / forward masks, and auto-TTLs, so
repeated queries on the same overlay skip all graph preprocessing.

Exactness contract (inherited from the PR-1 batch engine and enforced
by tests/test_engine.py + tests/test_multi_query.py):

  * a shared-stream batch of ONE reproduces ``run_query_reference``
    bit-for-bit;
  * ``rng="independent"`` (or explicit ``seeds``) reproduces
    ``run_query_reference(seed + q * n_trials + t)`` bit-for-bit for
    EVERY entry, for every registered policy.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import List, Optional, Sequence, Union

import numpy as np

from repro.engine.api import (PRECISIONS, Engine, Policy, QuerySpec,
                              TopKResult, get_policy)
from repro.engine.plan import NetworkPlan
from repro.engine.precision import check_tolerance
from repro.p2psim.graph import Topology
from repro.p2psim.overlay import Overlay
from repro.p2psim.metrics import QUERY_BYTES, BatchMetrics, QueryMetrics
from repro.p2psim.simulate import (SimParams, _latency_mode,
                                   _run_entries, run_query_reference)

_BM_FIELDS = ("m_bw", "m_rt", "b_bw", "b_rt", "response_time_s", "accuracy")
_ALL_BM_FIELDS = ("n_reached", "n_edges_pq", "avg_degree", "m_fw",
                  "b_fw") + _BM_FIELDS


def _batch_of_one(met: QueryMetrics) -> BatchMetrics:
    """Wrap one scalar QueryMetrics as a (1, 1) BatchMetrics."""
    bm = BatchMetrics.empty(met.algorithm, 1, 1)
    for f in _ALL_BM_FIELDS:
        getattr(bm, f)[0, 0] = getattr(met, f)
    return bm


def _slice_rows(bm: BatchMetrics, lo: int, n_queries: int,
                n_trials: int) -> BatchMetrics:
    """Reshape rows [lo, lo + Q*T) of a flat (N, 1) batch to (Q, T)."""
    out = BatchMetrics.empty(bm.algorithm, n_queries, n_trials)
    hi = lo + n_queries * n_trials
    for f in _ALL_BM_FIELDS:
        getattr(out, f)[:] = getattr(bm, f)[lo:hi, 0].reshape(
            n_queries, n_trials)
    return out


class SimEngine(Engine):
    """Unified Top-k engine backend over the overlay simulator.

    ``backend`` selects the sweep implementation:

      * ``"numpy"`` (default) — the vectorized numpy batch engine;
      * ``"jax"`` — jitted XLA sweeps over the plan's depth-bucketed
        slices and static merge-fold schedule
        (``repro.engine.sim_jax``), routing the bottom-up k-list merge
        through the Pallas bitonic kernel on TPU.
        Bit-for-bit equal to the numpy backend in every RNG mode
        (the stochastic inputs are the same numpy draws), INCLUDING
        churn: finite ``lifetime_mean_s`` runs in the same jitted
        sweep via validity masks and the plan's static reroute tables
        — no numpy fallback.  The only policy that still executes on
        the numpy reference path is the two-round ``fd-stats``
        heuristic; that fallback is recorded on
        ``TopKResult.backend_used`` and warned about once per engine.

    ``use_pallas`` (jax backend only): None = auto (Pallas on TPU, the
    jnp merge oracle elsewhere); True forces the Pallas kernels, in
    interpret mode off-TPU.

    ``precision`` (jax backend only): ``"f64"`` (default — the
    bit-exactness contract vs the scalar reference), ``"f32"`` or
    ``"bf16"`` (the sweeps run end-to-end in reduced precision; the
    result carries the TOLERANCE contract instead — see
    :mod:`repro.engine.precision`).  A spec's ``precision`` field
    overrides the engine default per request.  With
    ``validate_precision=True`` (default) every reduced-precision
    execution also runs the f64 sweep and records the measured
    contract (top-k recall + score rtol) in
    ``TopKResult.extras["tolerance"]``; benchmarks switch it off to
    time the reduced sweep alone.

    ``shard`` (jax backend only): run the forward/merge sweep through
    ``shard_map`` over all local devices on the batch-entry axis —
    each device holds only its slice of the per-entry working set
    (how million-peer plans fit in device memory).
    """

    backend = "sim"

    def __init__(self, top: Optional[Union[Topology, NetworkPlan]] = None,
                 params: Optional[SimParams] = None, *,
                 backend: str = "numpy",
                 use_pallas: Optional[bool] = None,
                 precision: str = "f64",
                 validate_precision: bool = True,
                 shard: bool = False):
        """Build the engine (and compile ``top``'s plan when given)."""
        if backend not in ("numpy", "jax"):
            raise ValueError("backend must be 'numpy' or 'jax', "
                             f"got {backend!r}")
        if precision not in PRECISIONS:
            raise ValueError(
                f"precision must be one of {PRECISIONS}, got {precision!r}")
        if backend != "jax" and precision != "f64":
            raise ValueError(
                "reduced precision requires backend='jax' — the numpy "
                "reference sweep is the f64 ground truth")
        self.params = params if params is not None else SimParams()
        self.plan: Optional[NetworkPlan] = None
        self.backend = "sim" if backend == "numpy" else "sim-jax"
        self._backend = backend
        self._use_pallas = use_pallas
        self._precision = precision
        self._validate_precision = validate_precision
        self._shard = shard
        self._warned_fallback = False
        if top is not None:
            self.prepare(top)

    def _fallback(self, reason: str) -> str:
        """Record a numpy-path fallback; warn AT MOST ONCE per engine."""
        if self._backend == "jax" and not self._warned_fallback:
            self._warned_fallback = True
            warnings.warn(
                f"SimEngine(backend='jax'): {reason}; running on the "
                "numpy reference path (reported on "
                "TopKResult.backend_used)", RuntimeWarning, stacklevel=4)
        return "sim"

    def prepare(self, top: Union[Topology, Overlay, NetworkPlan]
                ) -> NetworkPlan:
        """Compile (or adopt) the overlay's NetworkPlan.

        Passing a live :class:`~repro.p2psim.overlay.Overlay` binds the
        plan to it: every subsequent ``run`` / ``run_many`` re-resolves
        the plan against the overlay's current version
        (:meth:`NetworkPlan.sync` — incremental, not a recompile), so
        the engine keeps serving while the network churns."""
        self.plan = top if isinstance(top, NetworkPlan) else NetworkPlan(top)
        return self.plan

    def run(self, spec: Optional[QuerySpec] = None,
            policy: Union[str, Policy] = "fd-dynamic", *,
            params: Optional[SimParams] = None) -> TopKResult:
        """Execute ``spec`` under ``policy`` on the prepared overlay.

        This is the batch-of-1 case of :meth:`run_many`.
        """
        spec = spec if spec is not None else QuerySpec()
        return self.run_many([spec], [policy], params=params)[0]

    # ---- dynamic batching (run_many) -------------------------------------

    def _effective(self, spec: QuerySpec,
                   params: Optional[SimParams]) -> SimParams:
        """The ``SimParams`` this spec executes under (spec overrides
        applied)."""
        p = params if params is not None else self.params
        if spec.k is not None:
            p = dataclasses.replace(p, k=spec.k)
        if spec.seed is not None:
            p = dataclasses.replace(p, seed=spec.seed)
        if spec.latency_model is not None:
            p = dataclasses.replace(p, latency_model=spec.latency_model)
        return p

    @staticmethod
    def _coalescable(spec: QuerySpec, pol: Policy) -> bool:
        """True when the spec's entries can be fused with other specs'
        onto one sweep without changing a single drawn bit.

        Independent-stream entries (``rng="independent"`` or explicit
        ``seeds``) draw from their own generators, so their results
        depend only on (origin, entry seed, params, policy) — fusing is
        free.  A SHARED-stream spec draws batch-shaped arrays from one
        generator, so its draws depend on the whole batch shape — except
        for a batch of ONE, which is bit-for-bit the scalar reference on
        its seed, i.e. exactly the independent entry with that seed.
        Multi-entry shared specs therefore execute alone; the two-round
        ``fd-stats`` heuristic always does.
        """
        if pol.algorithm == "fd-stats":
            return False
        return spec.independent or (len(spec.origins) * spec.n_trials == 1)

    def _entry_seeds(self, spec: QuerySpec, p: SimParams) -> np.ndarray:
        """Per-entry RNG seeds, flattened — explicit ``seeds`` verbatim,
        else the engine's ``seed + q * n_trials + t`` derivation."""
        Q, T = len(spec.origins), spec.n_trials
        if spec.seeds is not None:
            seeds = np.asarray(spec.seeds, dtype=np.int64)
            if seeds.shape != (Q, T):
                raise ValueError(
                    f"seeds must be ({Q}, {T}), got {seeds.shape}")
            return seeds.reshape(-1)
        return p.seed + np.arange(Q * T, dtype=np.int64)

    def run_many(self, specs: Sequence[QuerySpec],
                 policies: Union[str, Policy,
                                 Sequence[Union[str, Policy]]]
                 = "fd-dynamic", *,
                 params: Optional[SimParams] = None) -> List[TopKResult]:
        """Execute a request batch, coalescing compatible specs.

        Specs sharing an execution signature — same resolved ``Policy``
        and same effective ``(k, latency_model)`` — whose entries are
        independently seeded (see :meth:`_coalescable`) are fused onto
        ONE batched sweep: their (origin, seed) entries concatenate into
        a single flattened spec with explicit per-entry seeds, reusing
        the plan's cached statics / ``DepthSlices`` and (on the jax
        backend) one jit trace for the whole group.  Every returned
        result is entry-wise bit-exact with a sequential ``run`` of its
        spec; ``TopKResult.batch_size`` records how many requests shared
        the executed sweep.
        """
        pols = self._zip_policies(specs, policies)
        results: List[Optional[TopKResult]] = [None] * len(specs)
        groups: dict = {}               # signature -> [request index]
        for i, (spec, pol) in enumerate(zip(specs, pols)):
            p = self._effective(spec, params)
            if not self._coalescable(spec, pol):
                results[i] = self._execute(spec, pol, p)
                continue
            prec = spec.precision or self._precision
            groups.setdefault((pol, p.k, p.latency_model, prec),
                              []).append(i)
        for (pol, k, lm, prec), idxs in groups.items():
            if len(idxs) == 1:          # nothing to fuse: direct path
                i = idxs[0]
                results[i] = self._execute(
                    specs[i], pol, self._effective(specs[i], params))
                continue
            origins, seeds, shapes = [], [], []
            for i in idxs:
                spec = specs[i]
                p = self._effective(spec, params)
                origins.append(np.repeat(
                    np.asarray(spec.origins, np.int64), spec.n_trials))
                seeds.append(self._entry_seeds(spec, p))
                shapes.append((len(spec.origins), spec.n_trials))
            fused = QuerySpec(
                origins=tuple(int(o) for o in np.concatenate(origins)),
                n_trials=1, k=k, latency_model=lm, precision=prec,
                seeds=np.concatenate(seeds)[:, None])
            res = self._execute(fused, pol,
                                self._effective(fused, params))
            lo = 0
            for i, (Q, T) in zip(idxs, shapes):
                hi = lo + Q * T
                results[i] = dataclasses.replace(
                    res, metrics=_slice_rows(res.metrics, lo, Q, T),
                    values=(None if res.values is None else
                            res.values.reshape(-1, k)[lo:hi]
                            .reshape(Q, T, k)),
                    indices=(None if res.indices is None else
                             res.indices.reshape(-1, k)[lo:hi]
                             .reshape(Q, T, k)),
                    batch_size=len(idxs), extras=dict(res.extras))
                lo += Q * T
        return results

    def _execute(self, spec: QuerySpec, pol: Policy,
                 p: SimParams) -> TopKResult:
        """Run one (already resolved) spec on the prepared overlay."""
        if self.plan is None:
            raise RuntimeError("call SimEngine.prepare(topology) first")
        if self.plan.overlay is not None:
            self.plan.sync()              # live overlay: catch up by version
        _latency_mode(self.plan.top, p)   # validate model name + coords
        if pol.algorithm == "fd-stats":
            if (spec.precision or self._precision) != "f64":
                raise ValueError(
                    "fd-stats runs on the scalar reference path, which "
                    "is f64-only; request precision='f64' (or None)")
            return self._run_stats(spec, pol, p)

        origins = np.atleast_1d(np.asarray(spec.origins, dtype=np.int64))
        Q, T = len(origins), spec.n_trials
        ent_seeds = self._entry_seeds(spec, p)
        prec = spec.precision or self._precision
        if prec != "f64" and self._backend != "jax":
            raise ValueError(
                f"spec requests precision={prec!r} but the numpy backend "
                "only runs f64 (it IS the ground truth); use "
                "SimEngine(backend='jax')")

        fw_strategy = ("basic" if pol.algorithm in ("cn", "cn_star")
                       else pol.strategy)
        n_statics = len(self.plan._statics)
        t0 = time.perf_counter()
        sts, st_of_q = self.plan.origin_statics(origins, p.ttl, fw_strategy)
        # statics wall counts as compile only when this call actually
        # BUILT something — a warm plan reports 0.0, so serving-layer
        # assertions on "no compile on the steady path" hold
        compile_s = (time.perf_counter() - t0
                     if len(self.plan._statics) > n_statics else 0.0)
        ent_st = np.repeat(st_of_q, T)
        ent_origin = np.repeat(origins, T)
        # replica placement is retrieval-phase only (FD paths); the CN
        # baselines never enter the owner-fetch fallback
        rep = (None if pol.algorithm in ("cn", "cn_star")
               else self.plan.replica_table(p))
        extras: dict = {}
        t0 = time.perf_counter()
        if self._backend == "jax":
            from repro.engine.sim_jax import run_entries_jax
            res = run_entries_jax(self.plan, sts, ent_st, ent_origin,
                                  ent_seeds, self.plan.top.n, p,
                                  pol.algorithm, pol.dynamic,
                                  pol.lifetime_mean_s, spec.independent,
                                  use_pallas=self._use_pallas,
                                  replicas=rep, precision=prec,
                                  shard=self._shard)
            used = "sim-jax"
        else:
            res = _run_entries(sts, ent_st, ent_origin, ent_seeds,
                               self.plan.top.n, p, pol.algorithm,
                               pol.dynamic, pol.lifetime_mean_s,
                               spec.independent, replicas=rep)
            used = "sim"
        run_s = time.perf_counter() - t0
        compile_s += res.pop("jax_compile_s", 0.0)
        traces = res.pop("jax_traces", 0)
        if traces:
            extras["jax_traces"] = traces
        vals = res.pop("values", None)
        owns = res.pop("owners", None)
        if prec != "f64" and self._validate_precision:
            # the tolerance contract: rerun the SAME entries in f64 and
            # measure recall / rtol of the reduced result against it
            res64 = run_entries_jax(self.plan, sts, ent_st, ent_origin,
                                    ent_seeds, self.plan.top.n, p,
                                    pol.algorithm, pol.dynamic,
                                    pol.lifetime_mean_s, spec.independent,
                                    use_pallas=self._use_pallas,
                                    replicas=rep, precision="f64",
                                    shard=self._shard)
            report = check_tolerance(prec, vals, owns,
                                     res64["values"], res64["owners"])
            extras["tolerance"] = report.summary()

        bm = BatchMetrics.empty(pol.algorithm, Q, T)
        n_reached_s = np.array([len(st.idx) for st in sts], np.int64)
        n_edges_s = np.array([st.n_edges_pq for st in sts], np.int64)
        avg_deg_s = np.array([st.avg_degree for st in sts])
        bm.n_reached[:] = n_reached_s[st_of_q, None]
        bm.n_edges_pq[:] = n_edges_s[st_of_q, None]
        bm.avg_degree[:] = avg_deg_s[st_of_q, None]
        bm.m_fw[:] = res["m_fw"].reshape(Q, T)
        bm.b_fw[:] = res["m_fw"].reshape(Q, T) * QUERY_BYTES
        for f in _BM_FIELDS:
            getattr(bm, f)[:] = res[f].reshape(Q, T)
        return TopKResult(policy=pol.name, backend=self.backend, k=p.k,
                          backend_used=used, topology=self.plan.top.kind,
                          latency_model=p.latency_model, metrics=bm,
                          precision=prec,
                          values=(None if vals is None
                                  else vals.reshape(Q, T, p.k)),
                          indices=(None if owns is None
                                   else owns.reshape(Q, T, p.k)),
                          compile_s=compile_s, run_s=run_s,
                          extras=extras)

    # ---- statistics heuristic (paper §3.3 + Fig 7) ----------------------

    def _run_stats(self, spec: QuerySpec, pol: Policy,
                   p: SimParams) -> TopKResult:
        """Two-round protocol: round 1 full FD gathers per-child best-rank
        stats; round 2 forwards Q only to children whose best past score
        ranked above ``z * k`` in the parent's merged list."""
        used = self._fallback("the two-round fd-stats heuristic has no "
                              "jitted lowering")
        t_start = time.perf_counter()
        origins = np.atleast_1d(np.asarray(spec.origins, dtype=np.int64))
        if len(origins) != 1 or spec.n_trials != 1:
            raise ValueError("fd-stats runs one origin x one trial per call")
        if spec.seeds is not None:
            seeds = np.asarray(spec.seeds, dtype=np.int64)
            if seeds.shape != (1, 1):
                raise ValueError(f"seeds must be (1, 1), got {seeds.shape}")
            p = dataclasses.replace(p, seed=int(seeds[0, 0]))
        origin = int(origins[0])
        top = self.plan.top
        if p.ttl == 0:
            # resolve auto-TTL once from the plan cache and thread it
            # through both rounds (round 2 prunes AFTER TTL resolution,
            # so the full-topology eccentricity is the right value twice)
            p = dataclasses.replace(p, ttl=self.plan.auto_ttl(origin))
        met1, st = run_query_reference(top, origin, p, return_state=True)
        children = st["children"]
        ms = st["merged_scores"]
        n = top.n
        keep = np.ones(n, bool)
        k = p.k
        for v in range(n):
            for c in children[v]:
                if ms[v] is None or ms[c] is None:
                    continue
                # best rank of c's subtree contribution within v's merge
                in_c = np.isin(ms[v], ms[c])
                ranks = np.flatnonzero(in_c)
                best = ranks[0] if len(ranks) else k
                if best >= pol.z * k:
                    keep[c] = False
        met2, st2 = run_query_reference(top, origin, p, child_mask=keep,
                                        return_state=True)
        # accuracy of round 2 vs round-1 TRUTH (the full reach set) —
        # pruning shrinks P_Q, so met2.accuracy alone would be trivially 1
        reached1 = st["reached"]
        idx1 = np.flatnonzero(reached1)
        true_scores = st["scores"][idx1].reshape(-1)
        top_true = np.sort(true_scores)[::-1][:k]
        got = st2["merged_scores"][origin]
        acc = float(np.intersect1d(top_true, got).size) / k \
            if got is not None else 0.0
        reduction = 1.0 - met2.total_bytes / max(met1.total_bytes, 1)
        return TopKResult(
            policy=pol.name, backend=self.backend, k=k,
            backend_used=used, topology=top.kind,
            latency_model=p.latency_model, metrics=_batch_of_one(met2),
            run_s=time.perf_counter() - t_start,
            extras={"metrics_full": met1, "metrics_pruned": met2,
                    "comm_reduction": reduction, "accuracy": acc,
                    "z": pol.z})
