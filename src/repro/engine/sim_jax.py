"""SimEngine ``backend="jax"`` — jitted overlay sweeps at 100k-peer scale.

The numpy engine's two hot phases are lowered to XLA:

  * the per-depth forward-phase sweep — query arrival times down the
    BFS tree plus the Strategy-1 "who-sent-first" edge reduction; the
    per-level gather+add and the Appendix-A wait-propagation rule route
    through ``repro.kernels.sweep`` (jnp oracles by default, Pallas
    kernels with ``use_pallas=True`` — interpret mode on CPU, Mosaic
    on TPU);
  * the bottom-up k-list merge — the static fold schedule compiled into
    the plan's :class:`~repro.engine.plan.DepthSlices` executes only
    real pairwise merges (plus odd-slot carries), each one a fused
    bitonic merge network (max against the reversed partner, then
    log2(K) compare-exchange stages) — no ``top_k``, no sorts, no
    scatters, which XLA:CPU punishes by orders of magnitude.  On TPU
    (or with ``use_pallas=True``) the pairwise step routes through the
    Pallas bitonic kernel in ``repro.kernels.merge`` instead.

Everything stochastic is precomputed in numpy by the SHARED
``_precompute_draws`` (same RNG streams, same order as the scalar
reference), and the retrieval / accuracy epilogue is the shared numpy
code — so this backend is bit-for-bit equal to the numpy backend in
every RNG mode, and therefore to ``run_query_reference`` wherever the
numpy backend is (shared batch of one, independent streams).  With the
default ``precision="f64"`` the sweeps trace and run inside
``jaxcompat.enable_x64()``: float64 is what makes "same expression"
mean "same bits".

Reduced precision (``precision="f32"`` / ``"bf16"``) casts the shared
numpy draws once on the host and runs the forward sweep and merge
folds in that dtype end to end — no silent upcast anywhere (the merge
kernels preserve f32/bf16) — trading the bit contract for the
tolerance contract checked by :mod:`repro.engine.precision`: top-k
recall against the f64 ground truth plus an rtol bound on the scores.
The epilogue containers stay float64 (upcasts are exact), and the
ground-truth top-k is computed from the CAST scores so value matching
in the retrieval epilogue stays consistent with what the sweep saw.

Entry batches are padded to the next power of two (the pad rows repeat
a real entry; rows are independent, outputs are sliced back), so the
jit cache keys on size buckets instead of exact entry counts — a
serving workload with mixed fused batch sizes stops retracing per
shape.  Per-sweep compile time is measured (cache-miss detection via
the jit cache size) and returned as ``jax_compile_s`` / ``jax_traces``
so the serving layer can attribute latency honestly.  On accelerators
the five per-entry draw buffers are donated to the sweep — the level
arrays they produce replace them instead of doubling resident memory
across depth levels (donation is a no-op on CPU and is disabled
there).

``shard=True`` runs the same sweep through ``shard_map`` over all
local devices on the batch-entry axis (``jaxcompat`` mesh helpers, the
same compat layer the multi-device :class:`~repro.engine.device`
collectives are built on): entries are embarrassingly parallel, so
each device materializes only its slice of the (entries, n) working
set — that is what lets a million-peer plan's sweep fit when a single
host's slice would not.

Churn (finite ``lifetime_mean_s``, §4/§5.4) runs end-to-end in the
same jitted sweep — no numpy fallback:

  * exponential death times come from the SHARED numpy draws
    (``EntryDraws.death``), so the stochastic inputs stay bit-identical
    across backends;
  * a peer dead at its send time contributes ``inf`` arrivals and
    ``-inf`` k-list rows — pure masks, no data-dependent shapes;
  * §4.2 dead-parent rerouting folds over the plan's STATIC reroute
    candidate tables (``DepthSlices`` with ``reroute=True``): every
    grandchild is a fixed slot in an augmented merge schedule whose
    per-entry liveness mask ("my parent died, I did not") decides at
    run time whether it contributes — fixed-shape gather/select, like
    everything else here;
  * urgent-list forwarding (§4.1) and the reroute message accounting
    stay in the shared numpy epilogue, computed from the per-level
    ``alive`` masks the sweep returns.
"""
from __future__ import annotations

import contextlib
import functools
import math
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import jaxcompat
from repro.engine.plan import DepthSlices, NetworkPlan
from repro.engine.precision import np_dtype
from repro.kernels.merge.merge import _next_pow2
from repro.kernels.merge.ops import merge_scorelists
from repro.kernels.sweep import level_arrivals, wait_propagate
from repro.p2psim.metrics import ENTRY_BYTES_PAPER
from repro.p2psim.simulate import (SimParams, _accept_urgent_origin,
                                   _cn_entries, _empty_out,
                                   _entry_latencies, _precompute_draws,
                                   _reroute_counts, _retrieval_exact,
                                   _retrieval_shared,
                                   _true_topk_by_origin, wait_time)


def _merge_desc(va, ia, vb, ib, valid_a=None, valid_b=None):
    """Fused bitonic merge of two descending K-lists (K a power of two).

    ``max(a_i, reverse(b)_i)`` selects the top-K multiset of the union
    as a bitonic sequence; log2(K) half-cleaner stages re-sort it
    descending.  Pure elementwise min/max/select — XLA fuses the whole
    network into one pass.  Exact for distinct values (and the -inf
    padding only ever ties with itself beyond the real entries).

    ``valid_a`` / ``valid_b``: optional row masks — an invalid list
    (late child, churned-out peer, live-parent reroute slot) becomes
    -inf rows, which real scores always beat, so validity costs one
    fused select instead of a branch.
    """
    if valid_a is not None:
        va = jnp.where(valid_a[..., None], va, -jnp.inf)
    if valid_b is not None:
        vb = jnp.where(valid_b[..., None], vb, -jnp.inf)
    K = va.shape[-1]
    fb = vb[..., ::-1]
    fo = ib[..., ::-1]
    take = va >= fb
    v = jnp.where(take, va, fb)
    o = jnp.where(take, ia, fo)
    lane = np.arange(K)
    s = K // 2
    while s >= 1:
        # partner exchange via reshape+reverse (fusible, unlike stack):
        # lane l swaps with l ^ s inside each 2s block
        shp = v.shape[:-1] + (K // (2 * s), 2, s)
        vp = jnp.flip(v.reshape(shp), axis=-2).reshape(v.shape)
        op = jnp.flip(o.reshape(shp), axis=-2).reshape(o.shape)
        take_max = jnp.asarray(lane % (2 * s) < s)
        keep = (v >= vp) == take_max
        v = jnp.where(keep, v, vp)
        o = jnp.where(keep, o, op)
        s //= 2
    return v, o


def _merge_lists(va, ia, vb, ib, use_pallas: bool,
                 valid_a=None, valid_b=None):
    """One pairwise descending k-list merge (top-k of the union)."""
    if use_pallas:
        return merge_scorelists(
            va, ia, vb, ib, use_pallas=True,
            interpret=jax.default_backend() != "tpu",
            valid_a=valid_a, valid_b=valid_b)
    return _merge_desc(va, ia, vb, ib, valid_a, valid_b)


def _retire(pools, ret, ret_perm, valid=None):
    """Gather each finished segment's slot, in parent-ascending order.

    ``valid``: slot mask over the ROUND-0 pool.  Only round-0
    retirements (single-slot segments) can surface a never-merged input
    slot, so that is the only place the mask applies — every later
    retirement is a merge output, already mask-resolved.
    """
    parts = []
    for r, idx in enumerate(ret):
        if idx is None:
            continue
        seg = pools[r][:, idx]
        if valid is not None and r == 0:
            m = valid[:, idx]
            seg = jnp.where(m[..., None] if seg.ndim == 3 else m,
                            seg, -jnp.inf)
        parts.append(seg)
    return jnp.concatenate(parts, axis=1)[:, ret_perm]


def _fold_lists(cv, co, sched, use_pallas, valid=None):
    """Run the static fold schedule ``sched = (rounds, ret, ret_perm)``
    over the child (and, in churn mode, reroute-candidate) k-lists;
    returns each parent's merged top-k, in parent-ascending order.

    ``valid``: per-slot liveness over round 0's slots.  The mask is
    THREADED through the fold — merge inputs mask at the kernel, merge
    outputs are always valid, carried slots inherit — so no masked copy
    of the full child array is ever materialized.
    """
    rounds, ret, ret_perm = sched
    pools_v, pools_o = [cv], [co]
    vm = valid
    for mi_a, mi_b, pi in rounds:
        ma = mb = None
        if vm is not None:
            ma, mb = vm[:, mi_a], vm[:, mi_b]
        mv, mo = _merge_lists(cv[:, mi_a], co[:, mi_a],
                              cv[:, mi_b], co[:, mi_b], use_pallas,
                              ma, mb)
        if pi.shape[0]:
            mv = jnp.concatenate([mv, cv[:, pi]], axis=1)
            mo = jnp.concatenate([mo, co[:, pi]], axis=1)
            if vm is not None:
                vm = jnp.concatenate(
                    [jnp.ones(mv.shape[:1] + (mi_a.shape[0],), bool),
                     vm[:, pi]], axis=1)
        elif vm is not None:
            vm = jnp.ones(mv.shape[:2], bool)
        cv, co = mv, mo
        pools_v.append(mv)
        pools_o.append(mo)
    return (_retire(pools_v, ret, ret_perm, valid),
            _retire(pools_o, ret, ret_perm))


def _fold_max(a, lv):
    """Child-slot schedule, max-reduce: each parent's latest child
    arrival (dead children carry ``inf`` — the paper's waiting parent
    can only be released by its deadline)."""
    pools = [a]
    for mi_a, mi_b, pi in lv["rounds"]:
        ma = jnp.maximum(a[:, mi_a], a[:, mi_b])
        if pi.shape[0]:
            ma = jnp.concatenate([ma, a[:, pi]], axis=1)
        a = ma
        pools.append(ma)
    return _retire(pools, lv["ret"], lv["ret_perm"])


def _fd_sweep_impl(scores, t_exec, up_term, dn_term, death, wt, tqf, lam,
                   levels, els, rr, *, k, use_pallas, with_st1,
                   with_churn, with_reroute):
    """Forward + merge-and-backward sweeps of one origin's tree.

    Per-level functional form: level d's arrays are produced from level
    d±1's by static gathers — nothing is scattered into a global
    buffer.  Bit-parity contract (f64): every float expression groups
    exactly as the numpy sweep's; k-lists are padded to
    K = 2^ceil(log2 k) with -inf tails that never surface in the top k.
    In reduced precision every intermediate inherits the input dtype —
    the literal zero / -inf buffers below are created in the operand
    dtype precisely so no f32/bf16 value is ever silently upcast.

    The per-level gather+add (forward flood) and the Appendix-A wait
    rule dispatch through ``repro.kernels.sweep`` — jnp oracles or the
    Pallas kernels depending on ``use_pallas`` (same bits either way
    in f64).

    Churn (``with_churn``): a peer dead at its would-be send time gets
    ``send = inf`` (its arrival can never release a waiting parent) and
    -inf / -1 merged rows — the exact fill the numpy sweep commits.
    ``with_reroute`` additionally folds each level's static grandchild
    table (``rr_*``): a grandchild slot is live iff its parent died and
    it did not, which reproduces §4.2's "children of a dead peer send
    their lists to the grandparent".  All of it is masks over fixed
    shapes; the one scalar the masks hinge on — the peer's death time —
    comes from the shared numpy draws.
    """
    E = t_exec.shape[0]
    K = _next_pow2(k)
    dmax = len(levels) - 1
    interp = jax.default_backend() != "tpu"

    skip = None
    if with_st1:
        els_src, els_dst, cond = els
        send_at = tqf[None, :] + lam
        skip = ((send_at[:, els_dst] < send_at[:, els_src])
                & cond[None, :]).sum(axis=1)

    t_qs = [jnp.zeros((E, 1), t_exec.dtype)]
    for d in range(1, dmax + 1):
        lv = levels[d]
        t_qs.append(level_arrivals(t_qs[d - 1], dn_term[:, lv["vv"]],
                                   lv["par_pos"], use_pallas=use_pallas,
                                   interpret=interp))

    send = [None] * (dmax + 1)
    m_v = [None] * (dmax + 1)
    m_o = [None] * (dmax + 1)
    alive = [None] * (dmax + 1)
    for d in range(dmax, -1, -1):
        lv = levels[d]
        vv = lv["vv"]
        L = vv.shape[0]
        own_ready = t_qs[d] + t_exec[:, vv]
        deadline = t_qs[d] + wt[vv][None, :]
        death_lv = death[:, vv] if with_churn else None
        own_v = scores[:, vv]
        if K > k:
            own_v = jnp.concatenate(
                [own_v, jnp.full((E, L, K - k), -jnp.inf, own_v.dtype)],
                axis=2)
        own_o = jnp.broadcast_to(vv.astype(jnp.int32)[None, :, None],
                                 (E, L, K))
        a0 = None
        if "cnode" not in lv:                    # all leaves
            all_in = jnp.zeros((E, L), own_ready.dtype)
        else:
            a0 = send[d + 1][:, lv["c_in_next"]] + up_term[:, lv["cnode"]]
            # the parent's send time (needed for the on-time mask)
            # depends on all_in, a pure max over ALL child arrivals
            # (dead children contribute inf) — mask-free, exactly as
            # numpy computes it
            n_par = lv["ret_perm"].shape[0]
            am = _fold_max(a0, lv)
            all_in = jnp.concatenate(
                [am, jnp.zeros((E, L - n_par), am.dtype)],
                axis=1)[:, lv["asm_perm"]]
        if with_churn:
            s, snd = wait_propagate(own_ready, all_in, deadline,
                                    death=death_lv,
                                    use_pallas=use_pallas,
                                    interpret=interp)
        else:
            s = wait_propagate(own_ready, all_in, deadline,
                               use_pallas=use_pallas, interpret=interp)
        if a0 is None:
            mv, mo = own_v, own_o
        else:
            # on-time = arrived by the parent's (raw) send time; a dead
            # child's a0 is inf, so validity is already folded in
            ont = a0 <= s[:, lv["cpar_pos"]]
            cv0 = m_v[d + 1][:, lv["c_in_next"]]
            co0 = m_o[d + 1][:, lv["c_in_next"]]
            vmask = ont
            sched = (lv["rounds"], lv["ret"], lv["ret_perm"])
            if with_reroute and rr[d] is not None:
                # §4.2 reroute slots: level-(d+2) lists contribute to
                # their grandparent iff their parent died (their own
                # death is already folded into m_v's -inf rows)
                gv = m_v[d + 2][:, rr[d]["gc_pos"]]
                go = m_o[d + 2][:, rr[d]["gc_pos"]]
                gmask = ~alive[d + 1][:, rr[d]["gc_par_pos"]]
                cv0 = jnp.concatenate([cv0, gv], axis=1)
                co0 = jnp.concatenate([co0, go], axis=1)
                vmask = jnp.concatenate([ont, gmask], axis=1)
                sched = (rr[d]["rounds"], rr[d]["ret"],
                         rr[d]["ret_perm"])
            child_v, child_o = _fold_lists(cv0, co0, sched, use_pallas,
                                           valid=vmask)
            pv, po = _merge_lists(own_v[:, lv["par_sel"]],
                                  own_o[:, lv["par_sel"]],
                                  child_v, child_o, use_pallas)
            mv = jnp.concatenate(
                [pv, own_v[:, lv["leaf_sel"]]], axis=1)[:, lv["asm_perm"]]
            mo = jnp.concatenate(
                [po, own_o[:, lv["leaf_sel"]]], axis=1)[:, lv["asm_perm"]]
        if with_churn:
            alv = death_lv >= s
            alive[d] = alv
            send[d] = snd
            m_v[d] = jnp.where(alv[..., None], mv, -jnp.inf)
            m_o[d] = jnp.where(alv[..., None], mo, -1)
        else:
            send[d] = s
            m_v[d], m_o[d] = mv, mo
    return (tuple(send), tuple(v[:, :, :k] for v in m_v),
            tuple(o[:, :, :k] for o in m_o), skip,
            tuple(alive) if with_churn else None)


_SWEEP_STATICS = ("k", "use_pallas", "with_st1", "with_churn",
                  "with_reroute")

# buffer donation: each call converts fresh host draws to device
# buffers; donating the five big per-entry operands lets XLA reuse
# their memory for the level outputs instead of holding both live
# across the whole depth loop.  CPU XLA does not implement donation
# (it would only warn), so it is enabled on accelerators only.
_fd_sweep = jax.jit(
    _fd_sweep_impl, static_argnames=_SWEEP_STATICS,
    donate_argnums=(() if jax.default_backend() == "cpu"
                    else (0, 1, 2, 3, 4)))


@functools.lru_cache(maxsize=None)
def _sharded_fd_sweep(n_dev: int, k: int, use_pallas: bool,
                      with_st1: bool, with_churn: bool,
                      with_reroute: bool):
    """``_fd_sweep`` sharded over the batch-entry axis on all devices.

    Entries are embarrassingly parallel (each row is one query trial on
    its own tree), so ``shard_map`` splits every (entries, n) operand
    across a 1-D device mesh and each device runs the identical sweep
    on its slice — no collectives needed, and no device ever
    materializes the full working set.  Static tables (wait budgets,
    level slices, fold schedules) are replicated; the per-entry draws
    are split.  Built through the same ``jaxcompat`` mesh/shard_map
    compat layer as the ``DeviceEngine`` collectives.
    """
    P = jax.sharding.PartitionSpec
    mesh = jaxcompat.make_mesh((n_dev,), ("entries",))
    ent, rep = P("entries"), P()
    fn = functools.partial(_fd_sweep_impl, k=k, use_pallas=use_pallas,
                           with_st1=with_st1, with_churn=with_churn,
                           with_reroute=with_reroute)
    in_specs = (ent, ent, ent, ent,          # scores..dn_term
                ent if with_churn else rep,  # death (or empty stub)
                rep, rep,                    # wt, tqf
                ent if with_st1 else rep,    # lam (or empty stub)
                rep, rep, rep)               # levels, els, rr
    sharded = jaxcompat.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                  out_specs=ent,
                                  axis_names=("entries",))
    return jax.jit(sharded)


@jax.jit
def _cn_sweep(t_exec, dn_term, levels):
    """CN / CN* need only the arrival sweep: t_exec_done per level."""
    E = t_exec.shape[0]
    t_qs = [jnp.zeros((E, 1), t_exec.dtype)]
    for d in range(1, len(levels)):
        lv = levels[d]
        t_qs.append(t_qs[d - 1][:, lv["par_pos"]]
                    + dn_term[:, lv["vv"]])
    return tuple(tq + t_exec[:, lv["vv"]]
                 for tq, lv in zip(t_qs, levels))


def _conv_slice_field(f, v):
    if f.endswith("rounds"):
        return tuple(tuple(jnp.asarray(x) for x in rnd) for rnd in v)
    if f.endswith("ret"):
        return tuple(None if idx is None else jnp.asarray(idx)
                     for idx in v)
    return jnp.asarray(v)


def _device_slices(sl: DepthSlices):
    """DepthSlices as cached device arrays (one transfer per plan).

    The reroute (``rr_*``) tables are cached SEPARATELY and returned as
    their own per-level tuple: the static sweep's ``levels`` pytree
    never changes shape when a plan later serves churn policies, so its
    jit traces and device uploads stay valid.
    """
    cached = getattr(sl, "_device", None)
    if cached is None:
        levels = tuple({f: _conv_slice_field(f, v) for f, v in lv.items()
                        if not f.startswith("rr_")} for lv in sl.levels)
        els = (jnp.asarray(sl.els_src), jnp.asarray(sl.els_dst),
               jnp.asarray(sl.cond))
        cached = sl._device = (levels, els)
    rr = getattr(sl, "_device_rr", None)
    if rr is None and sl.reroute:
        rr = sl._device_rr = tuple(
            {f[3:]: _conv_slice_field(f, lv[f])
             for f in ("rr_gc_pos", "rr_gc_par_pos", "rr_rounds",
                       "rr_ret", "rr_ret_perm")}
            if "rr_rounds" in lv else None
            for lv in sl.levels)
    return cached + (rr,)


def _cache_entries(fn) -> int:
    """Size of a jitted function's trace cache (-1 when unknowable)."""
    try:
        return fn._cache_size()
    except Exception:
        return -1


def _pad_group(es: np.ndarray, E: int, n_dev: int):
    """Pad an entry group to its size bucket (next power of two,
    rounded up to a device-mesh multiple).

    Entry rows are independent, so the pad rows just repeat a real
    entry and the sweep outputs are sliced back to ``len(es)``; the
    jit cache then keys on O(log E) bucket sizes instead of every
    distinct fused batch size the serving layer produces.

    Returns ``(es_run, full)`` — ``full`` means "the group IS the whole
    batch, in order", letting callers skip the gather entirely.
    """
    m = len(es)
    B = _next_pow2(max(m, 1))
    if n_dev > 1:
        B = -(-B // n_dev) * n_dev
    if B == m:
        return es, m == E
    return np.concatenate([es, np.repeat(es[-1:], B - m)]), False


def run_entries_jax(plan: NetworkPlan, sts, ent_st: np.ndarray,
                    ent_origin: np.ndarray, seeds, n: int, p: SimParams,
                    algorithm: str, dynamic: bool, lifetime_mean_s: float,
                    independent: bool,
                    use_pallas: Optional[bool] = None,
                    replicas=None, precision: str = "f64",
                    shard: bool = False) -> dict:
    """Drop-in for the numpy ``_run_entries`` with jitted sweeps.

    Same contract, same outputs — and with the default
    ``precision="f64"`` the same bits; see the module docstring.
    ``precision="f32"`` / ``"bf16"`` runs the sweeps in reduced
    precision (tolerance contract).  ``shard=True`` splits the entry
    batch across all local devices via ``shard_map``.  Finite
    ``lifetime_mean_s`` (churn) runs in the same jitted sweep; there
    is no numpy fallback.  The returned dict carries two scalar
    side-channels next to the per-entry arrays: ``jax_compile_s`` (wall
    time of sweep calls that actually traced) and ``jax_traces``.
    """
    churn = not math.isinf(lifetime_mean_s)
    E = len(seeds)
    S = len(sts)
    k = p.k
    list_bytes = k * ENTRY_BYTES_PAPER
    ent_of_st = [np.flatnonzero(ent_st == s) for s in range(S)]
    # latency_model="edge": the embedding-derived latencies enter here
    # (inside up_term / dn_term / lat_o, same draws as the numpy
    # backend), so the jitted sweeps need no edge-vs-iid branch at all
    par_lat, origin_lat = _entry_latencies(sts, ent_st, p)
    draws = _precompute_draws(ent_origin, seeds, n, p, algorithm,
                              sts[0].fw_strategy, lifetime_mean_s,
                              independent, par_lat, origin_lat)
    out = _empty_out(E, k)
    out["jax_compile_s"] = 0.0
    out["jax_traces"] = 0
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    fp64 = precision == "f64"
    if fp64:
        def cast(a):
            return a
    else:
        red_dt = np_dtype(precision)

        def cast(a):
            return np.asarray(a, red_dt)
    # f64 needs the x64 flag for "same expression == same bits"; the
    # reduced modes must NOT enable it — the default f32 lattice is
    # exactly what keeps their int/float literals narrow
    x64 = jaxcompat.enable_x64 if fp64 else contextlib.nullcontext
    n_dev = jax.local_device_count() if shard else 1
    if n_dev == 1:
        shard = False

    def _timed(fn, *args, **kw):
        """Call a jitted sweep; attribute its wall time to compile when
        the call actually traced (jit cache grew)."""
        before = _cache_entries(fn)
        t0 = time.perf_counter()
        res = fn(*args, **kw)
        jax.block_until_ready(res)
        wall = time.perf_counter() - t0
        after = _cache_entries(fn)
        if after > before >= 0:
            out["jax_compile_s"] += wall
            out["jax_traces"] += after - before
        return res

    # ---- CN / CN*: arrival sweep on device, baseline math shared --------
    if algorithm in ("cn", "cn_star"):
        out["m_fw"][:] = np.array([st.m_basic for st in sts],
                                  np.int64)[ent_st]
        t_ex_done = np.full((E, n), np.inf)
        with x64():
            for si, st in enumerate(sts):
                es = ent_of_st[si]
                es_run, full = _pad_group(es, E, 1)
                m = len(es)
                sl = plan.depth_slices(st)
                levels, _, _ = _device_slices(sl)
                te = draws.t_exec if full else draws.t_exec[es_run]
                dn = draws.dn_term if full else draws.dn_term[es_run]
                ted = _timed(_cn_sweep, cast(te), cast(dn), levels)
                for d, lv in enumerate(sl.levels):
                    t_ex_done[np.ix_(es, lv["vv"])] = \
                        np.asarray(ted[d])[:m]
        _cn_entries(out, draws, sts, ent_st, ent_origin, t_ex_done, p,
                    algorithm)
        return out

    # ---- FD: jitted forward + merge sweeps per origin -------------------
    with_reroute = churn and dynamic
    send_t = np.full((E, n), np.inf)
    mvals = np.empty((E, n, k))
    mown = np.full((E, n, k), -1, np.int32)
    valid = np.zeros((E, n), bool) if churn else None
    with x64():
        for si, st in enumerate(sts):
            es = ent_of_st[si]
            m = len(es)
            es_run, full = _pad_group(es, E, n_dev)
            sl = plan.depth_slices(st, reroute=with_reroute)
            levels, els, rr = _device_slices(sl)
            with_st1 = st.fw_strategy != "basic"

            def _take(a):
                return a if full else a[es_run]
            tqf = lam = cast(np.zeros(0))
            if with_st1:
                tqf = cast(np.where(st.depth >= 0,
                                    st.depth * p.t_qsnd_s, np.inf))
                lam = cast(_take(draws.lam))
            death = cast(_take(draws.death)) if churn else cast(
                np.zeros(0))
            if shard:
                fd = _sharded_fd_sweep(n_dev, k, bool(use_pallas),
                                       with_st1, churn, with_reroute)
                kw = {}
            else:
                fd = _fd_sweep
                kw = dict(k=k, use_pallas=bool(use_pallas),
                          with_st1=with_st1, with_churn=churn,
                          with_reroute=with_reroute)
            send_d, mv_d, mo_d, skip, alive_d = _timed(
                fd, cast(_take(draws.scores)), cast(_take(draws.t_exec)),
                cast(_take(draws.up_term)), cast(_take(draws.dn_term)),
                death, cast(wait_time(st.ttl_rem, p)), tqf, lam,
                levels, els, rr if with_reroute else None, **kw)
            for d, lv in enumerate(sl.levels):
                rows = np.ix_(es, lv["vv"])
                send_t[rows] = np.asarray(send_d[d])[:m]
                mvals[rows] = np.asarray(mv_d[d])[:m]
                mown[rows] = np.asarray(mo_d[d])[:m]
                if churn:
                    valid[rows] = np.asarray(alive_d[d])[:m]
            out["m_fw"][es] = (st.fw_static + sl.n_els
                               - np.asarray(skip, np.int64)[:m]
                               if with_st1 else st.m_basic)

    # every reached peer that is still alive at its send time sends its
    # list exactly once (without churn that is everyone but the origin)
    if churn:
        for si, st in enumerate(sts):
            es = ent_of_st[si]
            n_alive = valid[np.ix_(es, st.idx)].sum(axis=1)
            out["m_bw"][es] += n_alive - 1        # origin never dies
            out["b_bw"][es] += (n_alive - 1) * list_bytes
    else:
        n_reached_arr = np.array([len(st.idx) for st in sts], np.int64)
        out["m_bw"] += n_reached_arr[ent_st] - 1
        out["b_bw"] += (n_reached_arr[ent_st] - 1) * list_bytes

    # ---- urgent lists (§4.1): late-arrival post-pass --------------------
    urgent: list = [[] for _ in range(E)]
    if dynamic:
        hop_term = p.latency_mean_s + list_bytes / p.bw_mean_Bps
        for si, st in enumerate(sts):
            es = ent_of_st[si]
            ch = st.kid_sorted
            if len(ch) == 0:
                continue
            pr = st.parent[ch]
            a = send_t[np.ix_(es, ch)] + draws.up_term[np.ix_(es, ch)]
            late = a > send_t[np.ix_(es, pr)]
            if churn:
                # a dead child never went urgent; a dead parent's
                # children reroute (counted below) instead
                late &= valid[np.ix_(es, ch)] & valid[np.ix_(es, pr)]
            if not late.any():
                continue
            d_par = st.depth[pr]
            ei, ci = np.nonzero(late)
            etas = a[ei, ci] + d_par[ci] * hop_term
            for e_, c_, eta in zip(es[ei], ch[ci], etas):
                urgent[int(e_)].append((eta, int(c_)))
            out["m_bw"][es] += (late * d_par[None, :]).sum(axis=1)
            out["b_bw"][es] += (late
                                * (d_par[None, :] * list_bytes)).sum(axis=1)

    # ---- §4.2 reroute accounting: one message per accepted list ---------
    if with_reroute:
        for si, st in enumerate(sts):
            es = ent_of_st[si]
            cnt = _reroute_counts(st, valid[es])
            out["m_bw"][es] += cnt
            out["b_bw"][es] += cnt * list_bytes

    # ground truth from the scores AS THE SWEEP SAW THEM (cast once,
    # compared in f64 — the upcast is exact): reduced-precision runs
    # must value-match the retrieval epilogue against cast scores, and
    # in f64 this is the identical array
    truth_scores = (draws.scores if fp64
                    else cast(draws.scores).astype(np.float64))
    top_true_all = _true_topk_by_origin(truth_scores, sts, ent_of_st, k)
    t_merge_done = send_t[np.arange(E), ent_origin] + p.merge_s
    _accept_urgent_origin(urgent, ent_origin, t_merge_done, mvals, mown,
                          valid, k)
    ar = np.arange(E)
    out["values"] = mvals[ar, ent_origin]
    out["owners"] = mown[ar, ent_origin].astype(np.int64)
    if draws.exact:
        _retrieval_exact(out, draws, ent_origin, t_merge_done, mvals,
                         mown, top_true_all, p, replicas)
    else:
        _retrieval_shared(out, draws, ent_origin, t_merge_done, mvals,
                          mown, top_true_all, p, replicas)
    return out
