"""SimEngine ``backend="jax"`` — jitted overlay sweeps at 100k-peer scale.

The numpy engine's two hot phases are lowered to XLA:

  * the per-depth forward-phase sweep — query arrival times down the
    BFS tree plus the Strategy-1 "who-sent-first" edge reduction;
  * the bottom-up k-list merge — the static fold schedule compiled into
    the plan's :class:`~repro.engine.plan.DepthSlices` executes only
    real pairwise merges (plus odd-slot carries), each one a fused
    bitonic merge network (max against the reversed partner, then
    log2(K) compare-exchange stages) — no ``top_k``, no sorts, no
    scatters, which XLA:CPU punishes by orders of magnitude.  On TPU
    (or with ``use_pallas=True``) the pairwise step routes through the
    Pallas bitonic kernel in ``repro.kernels.merge`` instead.

Everything stochastic is precomputed in numpy by the SHARED
``_precompute_draws`` (same RNG streams, same order as the scalar
reference), and the retrieval / accuracy epilogue is the shared numpy
code — so this backend is bit-for-bit equal to the numpy backend in
every RNG mode, and therefore to ``run_query_reference`` wherever the
numpy backend is (shared batch of one, independent streams).  The
sweeps trace and run inside ``jaxcompat.enable_x64()``: float64 is what
makes "same expression" mean "same bits".

The jit cache keys on the tree's level/round size profile plus
(n_entries, k) — origin identities travel as device-cached index
arrays, so repeated runs on a prepared plan never recompile.

Churn (finite ``lifetime_mean_s``) keeps the numpy path: dead-parent
rerouting is a sparse per-event process the dense sweep has no business
emulating (``SimEngine`` falls back transparently).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import jaxcompat
from repro.engine.plan import DepthSlices, NetworkPlan
from repro.kernels.merge.merge import _next_pow2
from repro.kernels.merge.ops import merge_scorelists
from repro.p2psim.metrics import ENTRY_BYTES_PAPER
from repro.p2psim.simulate import (SimParams, _accept_urgent_origin,
                                   _cn_entries, _empty_out,
                                   _precompute_draws, _retrieval_exact,
                                   _retrieval_shared, _true_topk_by_origin,
                                   wait_time)


def _merge_desc(va, ia, vb, ib):
    """Fused bitonic merge of two descending K-lists (K a power of two).

    ``max(a_i, reverse(b)_i)`` selects the top-K multiset of the union
    as a bitonic sequence; log2(K) half-cleaner stages re-sort it
    descending.  Pure elementwise min/max/select — XLA fuses the whole
    network into one pass.  Exact for distinct values (and the -inf
    padding only ever ties with itself beyond the real entries).
    """
    K = va.shape[-1]
    fb = vb[..., ::-1]
    fo = ib[..., ::-1]
    take = va >= fb
    v = jnp.where(take, va, fb)
    o = jnp.where(take, ia, fo)
    lane = np.arange(K)
    s = K // 2
    while s >= 1:
        # partner exchange via reshape+reverse (fusible, unlike stack):
        # lane l swaps with l ^ s inside each 2s block
        shp = v.shape[:-1] + (K // (2 * s), 2, s)
        vp = jnp.flip(v.reshape(shp), axis=-2).reshape(v.shape)
        op = jnp.flip(o.reshape(shp), axis=-2).reshape(o.shape)
        take_max = jnp.asarray(lane % (2 * s) < s)
        keep = (v >= vp) == take_max
        v = jnp.where(keep, v, vp)
        o = jnp.where(keep, o, op)
        s //= 2
    return v, o


def _merge_lists(va, ia, vb, ib, use_pallas: bool):
    """One pairwise descending k-list merge (top-k of the union)."""
    if use_pallas:
        return merge_scorelists(
            va, ia, vb, ib, use_pallas=True,
            interpret=jax.default_backend() != "tpu")
    return _merge_desc(va, ia, vb, ib)


def _retire(pools, lv):
    """Gather each finished segment's slot, in parent-ascending order."""
    parts = [pools[r][:, idx] for r, idx in enumerate(lv["ret"])
             if idx is not None]
    return jnp.concatenate(parts, axis=1)[:, lv["ret_perm"]]


def _fold_lists(cv, co, lv, use_pallas):
    """Run the level's static fold schedule over the (masked) child
    k-lists; returns each parent's merged children top-k, in
    parent-ascending order."""
    pools_v, pools_o = [cv], [co]
    for mi_a, mi_b, pi in lv["rounds"]:
        mv, mo = _merge_lists(cv[:, mi_a], co[:, mi_a],
                              cv[:, mi_b], co[:, mi_b], use_pallas)
        if pi.shape[0]:
            mv = jnp.concatenate([mv, cv[:, pi]], axis=1)
            mo = jnp.concatenate([mo, co[:, pi]], axis=1)
        cv, co = mv, mo
        pools_v.append(mv)
        pools_o.append(mo)
    return _retire(pools_v, lv), _retire(pools_o, lv)


def _fold_max(a, lv):
    """Same schedule, max-reduce: each parent's latest child arrival."""
    pools = [a]
    for mi_a, mi_b, pi in lv["rounds"]:
        ma = jnp.maximum(a[:, mi_a], a[:, mi_b])
        if pi.shape[0]:
            ma = jnp.concatenate([ma, a[:, pi]], axis=1)
        a = ma
        pools.append(ma)
    return _retire(pools, lv)


@functools.partial(jax.jit, static_argnames=("k", "use_pallas",
                                             "with_st1"))
def _fd_sweep(scores, t_exec, up_term, dn_term, wt, tqf, lam, levels,
              els, *, k, use_pallas, with_st1):
    """Forward + merge-and-backward sweeps of one origin's tree.

    Per-level functional form: level d's arrays are produced from level
    d±1's by static gathers — nothing is scattered into a global
    buffer.  Bit-parity contract: every float expression groups exactly
    as the numpy sweep's; k-lists are padded to K = 2^ceil(log2 k) with
    -inf tails that never surface in the top k.
    """
    E = t_exec.shape[0]
    K = _next_pow2(k)
    dmax = len(levels) - 1

    skip = None
    if with_st1:
        els_src, els_dst, cond = els
        send_at = tqf[None, :] + lam
        skip = ((send_at[:, els_dst] < send_at[:, els_src])
                & cond[None, :]).sum(axis=1)

    t_qs = [jnp.zeros((E, 1))]
    for d in range(1, dmax + 1):
        lv = levels[d]
        t_qs.append(t_qs[d - 1][:, lv["par_pos"]]
                    + dn_term[:, lv["vv"]])

    send = [None] * (dmax + 1)
    m_v = [None] * (dmax + 1)
    m_o = [None] * (dmax + 1)
    for d in range(dmax, -1, -1):
        lv = levels[d]
        vv = lv["vv"]
        L = vv.shape[0]
        own_ready = t_qs[d] + t_exec[:, vv]
        deadline = t_qs[d] + wt[vv][None, :]
        own_v = scores[:, vv]
        if K > k:
            own_v = jnp.concatenate(
                [own_v, jnp.full((E, L, K - k), -jnp.inf)], axis=2)
        own_o = jnp.broadcast_to(vv.astype(jnp.int32)[None, :, None],
                                 (E, L, K))
        if "cnode" not in lv:                    # all leaves
            all_in = jnp.zeros((E, L))
            send[d] = jnp.minimum(
                jnp.maximum(own_ready, all_in),
                jnp.maximum(deadline, own_ready))
            m_v[d], m_o[d] = own_v, own_o
            continue
        a0 = send[d + 1][:, lv["c_in_next"]] + up_term[:, lv["cnode"]]
        # the parent's send time (needed for the on-time mask) depends
        # on all_in, a pure max over ALL child arrivals — mask-free,
        # exactly as numpy computes it
        n_par = lv["ret_perm"].shape[0]
        all_in = jnp.concatenate(
            [_fold_max(a0, lv), jnp.zeros((E, L - n_par))],
            axis=1)[:, lv["asm_perm"]]
        s = jnp.minimum(jnp.maximum(own_ready, all_in),
                        jnp.maximum(deadline, own_ready))
        send[d] = s
        ont = a0 <= s[:, lv["cpar_pos"]]
        cv0 = jnp.where(ont[..., None],
                        m_v[d + 1][:, lv["c_in_next"]], -jnp.inf)
        co0 = m_o[d + 1][:, lv["c_in_next"]]
        child_v, child_o = _fold_lists(cv0, co0, lv, use_pallas)
        pv, po = _merge_lists(own_v[:, lv["par_sel"]],
                              own_o[:, lv["par_sel"]],
                              child_v, child_o, use_pallas)
        m_v[d] = jnp.concatenate(
            [pv, own_v[:, lv["leaf_sel"]]], axis=1)[:, lv["asm_perm"]]
        m_o[d] = jnp.concatenate(
            [po, own_o[:, lv["leaf_sel"]]], axis=1)[:, lv["asm_perm"]]
    return (tuple(send), tuple(v[:, :, :k] for v in m_v),
            tuple(o[:, :, :k] for o in m_o), skip)


@jax.jit
def _cn_sweep(t_exec, dn_term, levels):
    """CN / CN* need only the arrival sweep: t_exec_done per level."""
    E = t_exec.shape[0]
    t_qs = [jnp.zeros((E, 1))]
    for d in range(1, len(levels)):
        lv = levels[d]
        t_qs.append(t_qs[d - 1][:, lv["par_pos"]]
                    + dn_term[:, lv["vv"]])
    return tuple(tq + t_exec[:, lv["vv"]]
                 for tq, lv in zip(t_qs, levels))


def _device_slices(sl: DepthSlices):
    """DepthSlices as cached device arrays (one transfer per plan)."""
    cached = getattr(sl, "_device", None)
    if cached is None:
        def conv(f, v):
            if f == "rounds":
                return tuple(tuple(jnp.asarray(x) for x in rnd)
                             for rnd in v)
            if f == "ret":
                return tuple(None if idx is None else jnp.asarray(idx)
                             for idx in v)
            return jnp.asarray(v)
        levels = tuple({f: conv(f, v) for f, v in lv.items()}
                       for lv in sl.levels)
        els = (jnp.asarray(sl.els_src), jnp.asarray(sl.els_dst),
               jnp.asarray(sl.cond))
        cached = sl._device = (levels, els)
    return cached


def _sub(a: np.ndarray, es: np.ndarray, E: int) -> np.ndarray:
    return a if len(es) == E else a[es]


def run_entries_jax(plan: NetworkPlan, sts, ent_st: np.ndarray,
                    ent_origin: np.ndarray, seeds, n: int, p: SimParams,
                    algorithm: str, dynamic: bool, lifetime_mean_s: float,
                    independent: bool,
                    use_pallas: Optional[bool] = None) -> dict:
    """Drop-in for the numpy ``_run_entries`` with jitted sweeps.

    Same contract, same outputs, same bits — see the module docstring.
    Requires an infinite-lifetime (no-churn) policy; ``SimEngine``
    routes churn variants to the numpy path.
    """
    if not math.isinf(lifetime_mean_s):
        raise ValueError("the jax backend is churn-free; SimEngine falls "
                         "back to the numpy sweep for finite lifetimes")
    E = len(seeds)
    S = len(sts)
    k = p.k
    list_bytes = k * ENTRY_BYTES_PAPER
    ent_of_st = [np.flatnonzero(ent_st == s) for s in range(S)]
    draws = _precompute_draws(ent_origin, seeds, n, p, algorithm,
                              sts[0].fw_strategy, lifetime_mean_s,
                              independent)
    out = _empty_out(E)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"

    # ---- CN / CN*: arrival sweep on device, baseline math shared --------
    if algorithm in ("cn", "cn_star"):
        out["m_fw"][:] = np.array([st.m_basic for st in sts],
                                  np.int64)[ent_st]
        t_ex_done = np.full((E, n), np.inf)
        with jaxcompat.enable_x64():
            for s, st in enumerate(sts):
                es = ent_of_st[s]
                sl = plan.depth_slices(st)
                levels, _ = _device_slices(sl)
                ted = _cn_sweep(_sub(draws.t_exec, es, E),
                                _sub(draws.dn_term, es, E), levels)
                for d, lv in enumerate(sl.levels):
                    t_ex_done[np.ix_(es, lv["vv"])] = np.asarray(ted[d])
        _cn_entries(out, draws, sts, ent_st, ent_origin, t_ex_done, p,
                    algorithm)
        return out

    # ---- FD: jitted forward + merge sweeps per origin -------------------
    send_t = np.full((E, n), np.inf)
    mvals = np.empty((E, n, k))
    mown = np.full((E, n, k), -1, np.int32)
    with jaxcompat.enable_x64():
        for s, st in enumerate(sts):
            es = ent_of_st[s]
            sl = plan.depth_slices(st)
            levels, els = _device_slices(sl)
            with_st1 = st.fw_strategy != "basic"
            tqf = lam = np.zeros(0)
            if with_st1:
                tqf = np.where(st.depth >= 0, st.depth * p.t_qsnd_s,
                               np.inf)
                lam = _sub(draws.lam, es, E)
            send_d, mv_d, mo_d, skip = _fd_sweep(
                _sub(draws.scores, es, E), _sub(draws.t_exec, es, E),
                _sub(draws.up_term, es, E), _sub(draws.dn_term, es, E),
                wait_time(st.ttl_rem, p), tqf, lam, levels, els,
                k=k, use_pallas=bool(use_pallas), with_st1=with_st1)
            for d, lv in enumerate(sl.levels):
                rows = np.ix_(es, lv["vv"])
                send_t[rows] = np.asarray(send_d[d])
                mvals[rows] = np.asarray(mv_d[d])
                mown[rows] = np.asarray(mo_d[d])
            out["m_fw"][es] = (st.fw_static + sl.n_els
                               - np.asarray(skip, np.int64)
                               if with_st1 else st.m_basic)

    # no churn: every reached non-origin peer sends exactly once
    n_reached_arr = np.array([len(st.idx) for st in sts], np.int64)
    out["m_bw"] += n_reached_arr[ent_st] - 1
    out["b_bw"] += (n_reached_arr[ent_st] - 1) * list_bytes

    # ---- urgent lists (§4.1): late-arrival post-pass --------------------
    urgent: list = [[] for _ in range(E)]
    if dynamic:
        hop_term = p.latency_mean_s + list_bytes / p.bw_mean_Bps
        for s, st in enumerate(sts):
            es = ent_of_st[s]
            ch = st.kid_sorted
            if len(ch) == 0:
                continue
            pr = st.parent[ch]
            a = send_t[np.ix_(es, ch)] + draws.up_term[np.ix_(es, ch)]
            late = a > send_t[np.ix_(es, pr)]
            if not late.any():
                continue
            d_par = st.depth[pr]
            ei, ci = np.nonzero(late)
            etas = a[ei, ci] + d_par[ci] * hop_term
            for e_, c_, eta in zip(es[ei], ch[ci], etas):
                urgent[int(e_)].append((eta, int(c_)))
            out["m_bw"][es] += (late * d_par[None, :]).sum(axis=1)
            out["b_bw"][es] += (late
                                * (d_par[None, :] * list_bytes)).sum(axis=1)

    top_true_all = _true_topk_by_origin(draws.scores, sts, ent_of_st, k)
    t_merge_done = send_t[np.arange(E), ent_origin] + p.merge_s
    _accept_urgent_origin(urgent, ent_origin, t_merge_done, mvals, mown,
                          None, k)
    if draws.exact:
        _retrieval_exact(out, draws, ent_origin, t_merge_done, mvals,
                         mown, top_true_all, p)
    else:
        _retrieval_shared(out, draws, ent_origin, t_merge_done, mvals,
                          mown, top_true_all, p)
    return out
