"""One surface over every name registry the reproduction exposes.

Four registries follow the same ``register_* / get_* / available_*``
idiom; this module re-exports them so callers (and ``QuerySpec``-style
string configs) resolve every kind of name through one import:

  * **policies** (``repro.engine.api``) — query-execution policies
    ("fd-dynamic", "cn", ...) run by the engines;
  * **topologies** (``repro.p2psim.topologies``) — overlay generators
    ("ba", "waxman", "hierarchical", ...);
  * **repairs** (``repro.p2psim.overlay``) — overlay self-healing
    policies ("none", "reconnect") run by ``Overlay.remove_peer``;
  * **placements** (``repro.p2psim.simulate``) — replica placement
    policies ("random", "neighbor") named by
    ``SimParams.replication_placement``.

    from repro.engine import registry
    registry.get_repair("reconnect")
    registry.available_placements()          # ('neighbor', 'random')
"""
from repro.engine.api import (available_policies,  # noqa: F401
                              get_policy, register_policy)
from repro.p2psim.overlay import (available_repairs,  # noqa: F401
                                  get_repair, register_repair)
from repro.p2psim.simulate import (available_placements,  # noqa: F401
                                   get_placement, register_placement)
from repro.p2psim.topologies import (available_topologies,  # noqa: F401
                                     get_topology, register_topology)

__all__ = [
    "register_policy", "get_policy", "available_policies",
    "register_topology", "get_topology", "available_topologies",
    "register_repair", "get_repair", "available_repairs",
    "register_placement", "get_placement", "available_placements",
]
