"""The reduced-precision tolerance contract (ISSUE 10).

``SimEngine(backend="jax")`` can run its forward sweep and merge folds
in ``f32`` or ``bf16`` instead of the default ``f64``.  Reduced
precision abandons the repo's bit-exactness contract (the f64 jax
sweep == numpy batch == scalar reference in every RNG mode) and
replaces it with a TOLERANCE contract, checked per query entry against
the f64 ground truth:

  * **top-k set recall** — the fraction of the true top-k owner set
    recovered.  On well-separated scores (the generic case: scores are
    continuous draws, ties have measure zero in f64 but CAN collide
    after a bf16 cast) recall must be 1.0; rank swaps among
    near-degenerate scores only ever swap items whose scores agree to
    within the cast's epsilon, so the contract bounds the *score* gap
    instead of demanding set equality on ties.
  * **score rtol** — every reported top-k score matches the f64 score
    at the same rank within ``PRECISION_RTOL[precision]`` (relative,
    with an absolute epsilon guard for scores near zero).

The bounds come from the cast's machine epsilon amplified by the
merge-fold depth (scores pass through O(log n) pairwise merges, each a
comparison network — comparisons never create new values, so the only
error source is the initial cast plus the wait-time arithmetic):
``f32`` keeps ~7 significant digits (rtol 1e-4 is ~250 ulp of slack),
``bf16`` keeps ~2–3 (rtol 5e-2).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

#: relative score tolerance per reduced precision (see module docstring)
PRECISION_RTOL = {"f64": 0.0, "f32": 1e-4, "bf16": 5e-2}
#: absolute epsilon guard for scores near zero
PRECISION_ATOL = {"f64": 0.0, "f32": 1e-6, "bf16": 1e-3}


def np_dtype(precision: str):
    """The numpy dtype a precision name casts draws to.

    ``bf16`` needs the ``ml_dtypes`` package (a jax dependency, so it
    is present wherever the jax backend runs); raise a clear error if
    it is somehow absent rather than silently computing in f32.
    """
    if precision == "f64":
        return np.float64
    if precision == "f32":
        return np.float32
    if precision == "bf16":
        try:
            import ml_dtypes
        except ImportError as e:          # pragma: no cover - jax ships it
            raise RuntimeError(
                "precision='bf16' needs the ml_dtypes package "
                "(installed with jax)") from e
        return ml_dtypes.bfloat16
    raise ValueError(f"unknown precision {precision!r}")


@dataclasses.dataclass(frozen=True)
class ToleranceReport:
    """The measured contract of one reduced-precision run vs its f64
    ground truth.

    ``recall`` — mean over entries of |topk_lo ∩ topk_f64| / k on the
    owner sets; ``min_recall`` the worst entry.  ``max_rtol`` — the
    largest relative score deviation at matched ranks (0.0 when the
    lists agree bit-for-bit after the cast).  ``ok`` — the contract
    holds: ``max_rtol <= rtol_bound`` and, when scores are
    well-separated at the cast's resolution (``separated``), recall is
    exactly 1.0; on tied/near-degenerate scores only the rtol bound is
    enforced (the swap is between items the cast cannot distinguish).
    """

    precision: str
    recall: float
    min_recall: float
    max_rtol: float
    rtol_bound: float
    separated: bool
    ok: bool

    def summary(self) -> dict:
        """Flat dict for TopKResult.extras / bench rows."""
        return {"precision": self.precision, "recall": self.recall,
                "min_recall": self.min_recall, "max_rtol": self.max_rtol,
                "rtol_bound": self.rtol_bound,
                "separated": self.separated, "ok": self.ok}


def check_tolerance(precision: str, values_lo, owners_lo,
                    values_f64, owners_f64, *,
                    rtol: Optional[float] = None,
                    atol: Optional[float] = None) -> ToleranceReport:
    """Check a reduced-precision top-k result against the f64 truth.

    All four arrays are (E, k): per-entry top-k score lists (descending)
    and their owner ids.  Empty slots are -inf scores / owner -1 and
    must agree positionally (an empty slot is structural — it means the
    query reached fewer than k items — and no cast may change that).
    """
    rtol = PRECISION_RTOL[precision] if rtol is None else rtol
    atol = PRECISION_ATOL[precision] if atol is None else atol
    v_lo = np.asarray(values_lo, np.float64)
    v_hi = np.asarray(values_f64, np.float64)
    o_lo = np.asarray(owners_lo)
    o_hi = np.asarray(owners_f64)
    if v_lo.shape != v_hi.shape:
        raise ValueError(f"shape mismatch {v_lo.shape} vs {v_hi.shape}")
    E, k = v_hi.shape if v_hi.ndim == 2 else (1, v_hi.shape[-1])
    v_lo, v_hi = v_lo.reshape(E, k), v_hi.reshape(E, k)
    o_lo, o_hi = o_lo.reshape(E, k), o_hi.reshape(E, k)

    # owner-set recall per entry (empty slots excluded from the truth set)
    recalls = np.ones(E)
    for e in range(E):
        true = o_hi[e][o_hi[e] >= 0]
        if true.size:
            got = o_lo[e][o_lo[e] >= 0]
            recalls[e] = np.intersect1d(true, got).size / true.size

    # positional score rtol over non-empty slots; empty slots (-inf)
    # must agree exactly
    fin_hi, fin_lo = np.isfinite(v_hi), np.isfinite(v_lo)
    if not np.array_equal(fin_hi, fin_lo):
        # a slot filled on one side and empty on the other: structural
        # mismatch, report as an infinite deviation
        max_rtol = float("inf")
    elif fin_hi.any():
        denom = np.maximum(np.abs(v_hi[fin_hi]), atol / max(rtol, 1e-300)) \
            if rtol > 0 else np.maximum(np.abs(v_hi[fin_hi]), 1e-300)
        max_rtol = float(np.max(np.abs(v_lo[fin_hi] - v_hi[fin_hi])
                                / denom))
    else:
        max_rtol = 0.0

    # "well-separated at the cast's resolution": adjacent f64 ranks
    # differ by more than the rtol bound — then no cast-induced tie can
    # change the top-k SET and recall must be exactly 1.0
    if rtol > 0 and fin_hi.any() and k > 1:
        gaps = v_hi[:, :-1] - v_hi[:, 1:]
        both = fin_hi[:, :-1] & fin_hi[:, 1:]
        scale = np.maximum(np.abs(v_hi[:, :-1]), atol / rtol)
        separated = bool(np.all(gaps[both] > 2 * rtol * scale[both])) \
            if both.any() else True
    else:
        separated = True

    ok = max_rtol <= rtol and (not separated or bool(
        np.all(recalls == 1.0)))
    if precision == "f64":
        ok = max_rtol == 0.0 and bool(np.all(recalls == 1.0))
    return ToleranceReport(
        precision=precision, recall=float(recalls.mean()),
        min_recall=float(recalls.min()), max_rtol=max_rtol,
        rtol_bound=rtol, separated=separated, ok=ok)
