"""Unified Top-k query engine: QuerySpec + Policy registry + compiled
NetworkPlan, across the sim and device backends.

    from repro.engine import SimEngine, QuerySpec

    engine = SimEngine(topology)            # compiles a NetworkPlan once
    res = engine.run(QuerySpec(origins=(0, 7), n_trials=4), "fd-dynamic")
    res.metrics.summary()                   # per-entry BatchMetrics

    engine.run(QuerySpec(origins=(0, 7)), "cn-star")   # plan reused

    SimEngine(topology, backend="jax")      # jitted XLA sweeps — same
                                            # bits, 100k-peer scale

``SimEngine(backend="jax")`` lowers the forward and merge sweeps to
jitted JAX over the plan's cached ``DepthSlices`` (``sim_jax`` is
imported lazily, so the default numpy path stays JAX-free);
``DeviceEngine`` exposes the same surface over the JAX shard_map
collectives (also imported lazily).

For sustained concurrent load, ``QueryServer`` hosts warm engines
behind a bounded queue and a dynamic batcher that coalesces compatible
requests onto one sweep via ``Engine.run_many`` (see docs/SERVING.md):

    with QueryServer(SimEngine(topology, backend="jax")) as server:
        handle = server.submit(QuerySpec(origins=(0,)), "fd-dynamic")
        res = handle.result()
"""
from repro.engine.api import (Engine, Policy, QuerySpec,  # noqa: F401
                              TopKResult, available_policies, get_policy,
                              policy_from_legacy, register_policy)
from repro.engine.plan import NetworkPlan  # noqa: F401
from repro.engine.serve import (LatencyStats, PhaseStats,  # noqa: F401
                                QueryHandle, QueryServer, RequestTimeout,
                                ServerClosed, ServerConfig, ServerError,
                                ServerMetrics, ServerOverloaded)
from repro.engine.sim import SimEngine  # noqa: F401
from repro.p2psim.overlay import (Overlay, SessionEvent,  # noqa: F401
                                  apply_events, available_repairs,
                                  get_repair, random_session,
                                  register_repair)

__all__ = ["QuerySpec", "Policy", "TopKResult", "NetworkPlan", "Engine",
           "SimEngine", "DeviceEngine", "QueryServer", "QueryHandle",
           "ServerConfig", "ServerError", "ServerOverloaded",
           "RequestTimeout", "ServerClosed", "ServerMetrics",
           "LatencyStats", "PhaseStats", "Overlay", "SessionEvent",
           "random_session", "apply_events", "available_policies",
           "get_policy", "policy_from_legacy", "register_policy",
           "register_repair", "get_repair", "available_repairs"]


def __getattr__(name):
    """Resolve the lazy ``DeviceEngine`` export (imports JAX)."""
    if name == "DeviceEngine":
        from repro.engine.device import DeviceEngine
        return DeviceEngine
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
