"""Mutable overlay: live :class:`Topology` mutations with versioning.

The simulator's topologies are frozen snapshots; real unstructured P2P
networks churn BETWEEN queries too — peers join, leave, and the overlay
self-heals.  :class:`Overlay` is that live surface: it owns a
``Topology`` and exposes ``add_peer`` / ``remove_peer`` / ``add_edge`` /
``remove_edge``, each bumping a monotonically-increasing ``version`` and
appending a delta record to a journal.  ``repro.engine.NetworkPlan``
keys its compiled caches on that version and patches them incrementally
(``NetworkPlan.sync``) instead of recompiling from scratch — see
docs/OVERLAY.md for the invalidation tiers.

Mutation semantics:

  * **Peer ids are stable.**  ``remove_peer`` TOMBSTONES: the departed
    peer keeps its id with an empty adjacency (``n`` never shrinks), so
    every cached per-node array stays aligned and a query from/through
    the tombstone degenerates naturally (BFS never reaches it).
    ``add_peer`` appends id ``n``.
  * **Adjacency invariants are preserved** — each ``neighbors[u]`` stays
    a sorted ``int32`` array (the CSR/BFS tie-break contract), and the
    arrays are replaced, never mutated in place, so snapshots taken by
    an un-synced plan stay internally consistent.
  * **Repair policies** run as part of ``remove_peer(pid, repair=...)``:
    the paper's self-healing story (a departed peer's neighbors
    reconnect) is ``"reconnect"``; policies are registered via
    :func:`register_repair` (mirroring the Policy/Topology registries —
    one surface in ``repro.engine.registry``).

Session dynamics between queries ride on top: :func:`random_session`
draws a reproducible join/leave event stream and :func:`apply_events`
replays one onto an overlay.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.p2psim.graph import Topology

# --------------------------------------------------------------------------
# repair-policy registry (mirrors the Policy / Topology registries)
# --------------------------------------------------------------------------

# repair(overlay, pid, former_neighbors) -> None, called AFTER the
# departed peer's edges are gone; mutations it makes bump the version
RepairFn = Callable[["Overlay", int, np.ndarray], None]

_REPAIRS: Dict[str, RepairFn] = {}


def register_repair(name: str, fn: RepairFn) -> RepairFn:
    """Register an overlay self-healing policy under ``name``."""
    _REPAIRS[name] = fn
    return fn


def get_repair(name: str) -> RepairFn:
    """Look up a registered repair policy by name."""
    try:
        return _REPAIRS[name]
    except KeyError:
        raise KeyError(f"unknown repair policy {name!r}; registered: "
                       f"{available_repairs()}") from None


def available_repairs() -> Tuple[str, ...]:
    """Registered repair-policy names, sorted."""
    return tuple(sorted(_REPAIRS))


def _repair_none(ov: "Overlay", pid: int, former: np.ndarray) -> None:
    """No self-healing: the hole the departed peer leaves stays."""


def _repair_reconnect(ov: "Overlay", pid: int, former: np.ndarray) -> None:
    """The departed peer's neighbors reconnect pairwise along a chain.

    Consecutive former neighbors (ascending id) that are not already
    adjacent gain an edge — every path that used to run through the
    departed peer survives through the chain, so a connected overlay
    stays connected at the cost of ``deg - 1`` edges at most.
    """
    for a, b in zip(former[:-1], former[1:]):
        if not ov.has_edge(int(a), int(b)):
            ov.add_edge(int(a), int(b))


register_repair("none", _repair_none)
register_repair("reconnect", _repair_reconnect)


# --------------------------------------------------------------------------
# the mutable overlay
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OverlayDelta:
    """One journal record: the op plus the nodes whose adjacency changed."""

    version: int                  # version AFTER this mutation applied
    op: str                       # add_edge / remove_edge / add_peer / ...
    nodes: Tuple[int, ...]


class Overlay:
    """A live, versioned overlay wrapping one :class:`Topology`.

    ``Overlay(top)`` snapshots ``top`` (shallow copy of the adjacency
    list; per-node arrays are shared until replaced) so the caller's
    topology object is never mutated.  ``Overlay(top, copy=False)``
    adopts and mutates ``top`` in place.
    """

    def __init__(self, top: Topology, *, copy: bool = True):
        """Wrap (and by default snapshot) ``top``."""
        if copy:
            top = Topology(
                n=top.n, neighbors=list(top.neighbors), kind=top.kind,
                coords=None if top.coords is None else top.coords.copy(),
                lat_base_s=top.lat_base_s, lat_scale_s=top.lat_scale_s)
        self.top = top
        self._version = 0
        self._journal: List[OverlayDelta] = []

    # -- introspection -----------------------------------------------------

    @property
    def version(self) -> int:
        """Monotonically-increasing mutation counter (0 = as wrapped)."""
        return self._version

    @property
    def n(self) -> int:
        """Current peer-id space size (tombstones included)."""
        return self.top.n

    def degree(self, u: int) -> int:
        """Current degree of ``u`` (0 for tombstoned peers)."""
        return len(self.top.neighbors[u])

    def alive_peers(self) -> np.ndarray:
        """Ids of peers with at least one link (excludes tombstones)."""
        return np.flatnonzero(
            [len(a) > 0 for a in self.top.neighbors]).astype(np.int64)

    def has_edge(self, u: int, v: int) -> bool:
        """True when the undirected edge u–v exists."""
        a = self.top.neighbors[u]
        i = np.searchsorted(a, v)
        return bool(i < len(a) and a[i] == v)

    def deltas_since(self, version: int) -> List[OverlayDelta]:
        """Journal records applied after ``version`` (oldest first)."""
        return [d for d in self._journal if d.version > version]

    # -- mutations ---------------------------------------------------------

    def _check_node(self, u: int) -> int:
        u = int(u)
        if not 0 <= u < self.top.n:
            raise ValueError(f"peer id {u} out of range [0, {self.top.n})")
        return u

    def _record(self, op: str, nodes: Tuple[int, ...]) -> None:
        self._version += 1
        self._journal.append(OverlayDelta(self._version, op, nodes))

    @staticmethod
    def _insert(a: np.ndarray, v: int) -> np.ndarray:
        i = np.searchsorted(a, v)
        return np.insert(a, i, np.int32(v))

    @staticmethod
    def _delete(a: np.ndarray, v: int) -> np.ndarray:
        i = np.searchsorted(a, v)
        return np.delete(a, i)

    def add_edge(self, u: int, v: int) -> None:
        """Insert the undirected edge u–v (must not already exist)."""
        u, v = self._check_node(u), self._check_node(v)
        if u == v:
            raise ValueError(f"self-loop {u}-{v} not allowed")
        if self.has_edge(u, v):
            raise ValueError(f"edge {u}-{v} already exists")
        nb = self.top.neighbors
        nb[u] = self._insert(nb[u], v)
        nb[v] = self._insert(nb[v], u)
        self._record("add_edge", (u, v))

    def remove_edge(self, u: int, v: int) -> None:
        """Delete the undirected edge u–v (must exist)."""
        u, v = self._check_node(u), self._check_node(v)
        if not self.has_edge(u, v):
            raise ValueError(f"edge {u}-{v} does not exist")
        nb = self.top.neighbors
        nb[u] = self._delete(nb[u], v)
        nb[v] = self._delete(nb[v], u)
        self._record("remove_edge", (u, v))

    def add_peer(self, neighbors: Sequence[int] = (),
                 coords: Optional[Sequence[float]] = None) -> int:
        """Join a new peer (id ``n``) linked to ``neighbors``; returns
        its id.

        On a coordinate-carrying topology the new peer is placed at
        ``coords`` when given, else at the centroid of its neighbors
        (plane center when it joins link-less) — so the per-edge latency
        model keeps working on joined peers.
        """
        nbs = sorted({self._check_node(v) for v in neighbors})
        pid = self.top.n
        self.top.neighbors.append(np.zeros(0, np.int32))
        self.top.n = pid + 1
        if self.top.coords is not None:
            if coords is None:
                pos = (np.mean(self.top.coords[nbs], axis=0) if nbs
                       else np.full(2, 0.5))
            else:
                pos = np.asarray(coords, dtype=float)
            self.top.coords = np.vstack([self.top.coords, pos[None]])
        elif coords is not None:
            raise ValueError(
                f"topology {self.top.kind!r} carries no coordinates; "
                "cannot place the joining peer")
        self._record("add_peer", (pid,))
        for v in nbs:
            self.add_edge(pid, v)
        return pid

    def remove_peer(self, pid: int, repair: str = "none") -> np.ndarray:
        """Leave: tombstone ``pid`` (drop all incident edges, keep the
        id), then run the named repair policy over its former neighbors.
        Returns the former neighbor array."""
        pid = self._check_node(pid)
        fn = get_repair(repair)            # resolve BEFORE mutating
        nb = self.top.neighbors
        former = nb[pid].copy()
        for v in former:
            nb[v] = self._delete(nb[v], int(pid))
        nb[pid] = np.zeros(0, np.int32)
        self._record("remove_peer", (pid, *(int(v) for v in former)))
        fn(self, pid, former)
        return former


# --------------------------------------------------------------------------
# session dynamics: join/leave event streams between queries
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SessionEvent:
    """One session-dynamics event.

    ``kind="leave"`` removes ``peer``; ``kind="join"`` adds a fresh peer
    linked to ``neighbors`` (``peer`` is ignored on join — ids are
    assigned by the overlay).
    """

    kind: str                            # "join" | "leave"
    peer: int = -1
    neighbors: Tuple[int, ...] = ()


def random_session(overlay: Overlay, n_events: int, seed: int = 0,
                   join_prob: float = 0.5,
                   links_per_join: int = 2) -> List[SessionEvent]:
    """A reproducible join/leave stream against ``overlay``'s CURRENT
    state (events are drawn as if applied in order, so leave targets and
    join endpoints stay consistent under :func:`apply_events`)."""
    rng = np.random.default_rng(seed)
    alive = list(int(u) for u in overlay.alive_peers())
    next_id = overlay.n
    events: List[SessionEvent] = []
    for _ in range(n_events):
        if len(alive) > 1 and rng.random() >= join_prob:
            peer = alive.pop(int(rng.integers(len(alive))))
            events.append(SessionEvent("leave", peer=peer))
        else:
            m = min(links_per_join, len(alive))
            nbs = tuple(alive[int(i)] for i in
                        rng.choice(len(alive), size=m, replace=False))
            events.append(SessionEvent("join", neighbors=nbs))
            alive.append(next_id)
            next_id += 1
    return events


def apply_events(overlay: Overlay, events: Sequence[SessionEvent],
                 repair: str = "none") -> List[int]:
    """Replay ``events`` onto ``overlay`` (leaves run ``repair``);
    returns the ids assigned to the joins, in order."""
    joined: List[int] = []
    for ev in events:
        if ev.kind == "leave":
            overlay.remove_peer(ev.peer, repair=repair)
        elif ev.kind == "join":
            joined.append(overlay.add_peer(ev.neighbors))
        else:
            raise ValueError(f"unknown session event kind {ev.kind!r}")
    return joined
