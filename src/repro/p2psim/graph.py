"""BRITE-like topology generation (paper §5.1).

BRITE's two flat standard models live here:
  * Barabási–Albert preferential attachment (BRITE "BA") — power-law
    degrees, the shape observed for Gnutella; ``m=2`` gives the paper's
    average degree d(G) ≈ 4 [16].
  * Waxman (BRITE "RTWaxman") — random geometric with exponential
    distance decay.

The full family — BRITE-style two-level hierarchical, Gnutella-like
rewired power-law, small-world, random-regular — plus the topology
registry is in :mod:`repro.p2psim.topologies`.

Topologies are connected by construction (BA) or post-connected by
bridging components (Waxman).

A :class:`Topology` may carry per-node plane coordinates (``coords``),
which enable BRITE's distance-proportional link-latency model
(``SimParams.latency_model="edge"``): the latency of a link u–v is
``lat_base_s + lat_scale_s * ||coords[u] - coords[v]||`` instead of an
i.i.d. normal draw.  The defaults put the mean pair latency of a
unit-square embedding near the paper's 200 ms.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Topology:
    """One overlay: adjacency lists + optional plane embedding.

    ``coords`` (n, 2), when present, define the per-edge latency model
    via :meth:`pair_latency`; generators that have no natural embedding
    (flat BA) leave it ``None`` and support only the i.i.d. latency
    draw.
    """

    n: int
    neighbors: List[np.ndarray]          # adjacency lists (sorted int32)
    kind: str = "ba"
    coords: Optional[np.ndarray] = None  # (n, 2) plane positions
    lat_base_s: float = 0.010            # propagation floor (s)
    lat_scale_s: float = 0.380           # seconds per unit distance

    @property
    def n_edges(self) -> int:
        """Number of undirected edges."""
        return sum(len(a) for a in self.neighbors) // 2

    def degree(self) -> np.ndarray:
        """(n,) node degrees."""
        return np.array([len(a) for a in self.neighbors])

    def avg_degree(self) -> float:
        """Mean degree d(G)."""
        return 2.0 * self.n_edges / self.n

    def edge_set(self):
        """Yield every undirected edge once as (u, v) with u < v."""
        for u in range(self.n):
            for v in self.neighbors[u]:
                if u < v:
                    yield (u, int(v))

    def pair_latency(self, u, v) -> np.ndarray:
        """BRITE-style latency of a (u, v) link from the embedding.

        ``lat_base_s + lat_scale_s * euclidean_distance`` — the
        distance-proportional propagation delay BRITE assigns to every
        edge.  ``u`` / ``v`` broadcast (scalar against array is fine);
        requires ``coords``.
        """
        if self.coords is None:
            raise ValueError(
                f"topology {self.kind!r} has no node coordinates; the "
                "per-edge latency model needs a coordinate-carrying "
                "generator (see repro.p2psim.topologies)")
        cu = self.coords[u]
        cv = self.coords[v]
        d = np.sqrt(((cu - cv) ** 2).sum(axis=-1))
        return self.lat_base_s + self.lat_scale_s * d

    def edge_latencies(self, e_src: np.ndarray,
                       e_dst: np.ndarray) -> np.ndarray:
        """Per-edge latency array aligned with a directed edge list."""
        return self.pair_latency(e_src, e_dst)


def _to_topology(adj: List[set], kind: str,
                 coords: Optional[np.ndarray] = None) -> Topology:
    return Topology(
        n=len(adj),
        neighbors=[np.array(sorted(a), dtype=np.int32) for a in adj],
        kind=kind, coords=coords)


def _ba_adj(n: int, m: int, rng: np.random.Generator) -> List[set]:
    """BA preferential-attachment adjacency sets (``barabasi_albert``'s
    exact construction and RNG stream, reusable as a subgraph builder).
    """
    adj: List[set] = [set() for _ in range(n)]
    # seed clique of m+1 nodes
    core = min(m + 1, n)
    for u in range(core):
        for v in range(u + 1, core):
            adj[u].add(v)
            adj[v].add(u)
    # degree-proportional target sampling via repeated-endpoint list
    targets = []
    for u in range(core):
        targets.extend([u] * len(adj[u]))
    for u in range(core, n):
        chosen: set = set()
        while len(chosen) < min(m, u):
            cand = int(targets[rng.integers(len(targets))])
            if cand != u:
                chosen.add(cand)
        for v in chosen:
            adj[u].add(v)
            adj[v].add(u)
            targets.extend([u, v])
    return adj


def barabasi_albert(n: int, m: int = 2, seed: int = 0) -> Topology:
    """BA preferential attachment; avg degree -> 2m (paper's d(G)=4)."""
    rng = np.random.default_rng(seed)
    return _to_topology(_ba_adj(n, m, rng), "ba")


def _waxman_adj(pos: np.ndarray, alpha: float, beta: float,
                avg_degree: float, rng: np.random.Generator) -> List[set]:
    """Waxman adjacency sets over GIVEN positions (``waxman``'s exact
    edge-draw + nearest-pair bridging, reusable for the AS level of the
    hierarchical generator).  O(n^2) memory — flat-overlay scale only.
    """
    n = len(pos)
    d = np.sqrt(((pos[:, None] - pos[None]) ** 2).sum(-1))
    L = np.sqrt(2.0)
    p = beta * np.exp(-d / (alpha * L))
    np.fill_diagonal(p, 0.0)
    target_edges = avg_degree * n / 2.0
    p *= target_edges / max(p.sum() / 2.0, 1e-300)
    upper = np.triu(rng.random((n, n)) < p, k=1)
    adj: List[set] = [set() for _ in range(n)]
    for u, v in zip(*np.nonzero(upper)):
        adj[int(u)].add(int(v))
        adj[int(v)].add(int(u))
    # connect components along nearest pairs
    comp = _components(adj)
    while len(set(comp)) > 1:
        c0 = np.flatnonzero(comp == comp[0])
        c1 = np.flatnonzero(comp != comp[0])
        dd = d[np.ix_(c0, c1)]
        i, j = np.unravel_index(np.argmin(dd), dd.shape)
        u, v = int(c0[i]), int(c1[j])
        adj[u].add(v)
        adj[v].add(u)
        comp = _components(adj)
    return adj


def waxman(n: int, alpha: float = 0.15, beta: float = 0.2,
           avg_degree: float = 4.0, seed: int = 0) -> Topology:
    """Waxman: P(u~v) = beta * exp(-d(u,v) / (alpha * L)).

    Edge probability is globally rescaled to hit ``avg_degree``; the
    result is connected by bridging components along nearest pairs.
    The draw positions are kept as ``coords``, so Waxman overlays
    support the per-edge latency model.
    """
    rng = np.random.default_rng(seed)
    pos = rng.random((n, 2))
    adj = _waxman_adj(pos, alpha, beta, avg_degree, rng)
    return _to_topology(adj, "waxman", coords=pos)


def _components(adj: List[set]) -> np.ndarray:
    n = len(adj)
    comp = -np.ones(n, dtype=np.int64)
    cur = 0
    for s in range(n):
        if comp[s] >= 0:
            continue
        stack = [s]
        comp[s] = cur
        while stack:
            u = stack.pop()
            for v in adj[u]:
                if comp[v] < 0:
                    comp[v] = cur
                    stack.append(v)
        cur += 1
    return comp


def as_csr(top: Topology):
    """(indptr (n+1,), indices (2E,)) int64 CSR view of the adjacency.

    ``indices[indptr[u]:indptr[u+1]]`` are u's neighbors in sorted order —
    identical iteration order to ``top.neighbors[u]``.
    """
    counts = np.array([len(a) for a in top.neighbors], dtype=np.int64)
    indptr = np.zeros(top.n + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    if indptr[-1]:
        indices = np.concatenate(top.neighbors).astype(np.int64)
    else:
        indices = np.zeros(0, dtype=np.int64)
    return indptr, indices


def directed_edges(indptr: np.ndarray, indices: np.ndarray):
    """(e_src, e_dst) for every directed edge, grouped by src ascending,
    dst sorted within src — the exact order of the per-peer Python loops
    the batched engine replaces."""
    e_src = np.repeat(np.arange(len(indptr) - 1, dtype=np.int64),
                      np.diff(indptr))
    return e_src, indices


def bfs_tree_csr(indptr: np.ndarray, indices: np.ndarray, origin: int,
                 ttl: int, return_rank: bool = False):
    """Vectorized-per-level BFS, bit-for-bit identical to ``bfs_tree``.

    ``bfs_tree`` assigns ``parent[v]`` to the FIRST toucher — iterating
    the frontier in discovery order and neighbors in sorted order.  The
    same tie-break is reproduced here as the minimum position in the
    concatenated frontier-neighbor gather, so every downstream quantity
    (tree edges, wait times, merges) matches the scalar path exactly.

    With ``return_rank=True`` a fourth float64 array is returned:
    ``rank[v]`` = v's discovery index WITHIN ITS LEVEL (the frontier
    order), -1 for unreached nodes.  Ranks are only meaningful compared
    between same-depth nodes; they are the first-touch certificate the
    live-overlay tree patch (``repro.engine.plan``) uses to decide
    claim priority without re-running the sweep (float so patched-in
    joins can take fractional slots between existing claims).
    """
    n = len(indptr) - 1
    parent = -np.ones(n, dtype=np.int64)
    depth = -np.ones(n, dtype=np.int64)
    depth[origin] = 0
    rank = None
    if return_rank:
        rank = -np.ones(n, dtype=np.float64)
        rank[origin] = 0.0
    frontier = np.array([origin], dtype=np.int64)
    # first-touch position scratch, allocated once; only the entries a
    # level touches are reset afterwards
    sentinel = np.iinfo(np.int64).max
    first = np.full(n, sentinel, dtype=np.int64)
    lvl = 0
    while len(frontier) and lvl < ttl:
        starts = indptr[frontier]
        counts = indptr[frontier + 1] - starts
        total = int(counts.sum())
        if total == 0:
            break
        # ragged gather of all frontier neighbor lists, in frontier order
        offs = np.repeat(np.cumsum(counts) - counts, counts)
        pos_in_row = np.arange(total, dtype=np.int64) - offs
        cand = indices[np.repeat(starts, counts) + pos_in_row]
        src = np.repeat(frontier, counts)
        new = depth[cand] < 0
        cand_new = cand[new]
        if len(cand_new) == 0:
            break
        pos = np.flatnonzero(new)
        np.minimum.at(first, cand_new, pos)
        uniq = np.unique(cand_new)
        order_new = uniq[np.argsort(first[uniq])]   # discovery order
        parent[order_new] = src[first[order_new]]
        depth[order_new] = lvl + 1
        if rank is not None:
            rank[order_new] = np.arange(len(order_new), dtype=np.float64)
        first[uniq] = sentinel
        frontier = order_new
        lvl += 1
    if return_rank:
        return parent, depth, depth >= 0, rank
    return parent, depth, depth >= 0


def bfs_tree_csr_multi(indptr: np.ndarray, indices: np.ndarray,
                       origins: np.ndarray, ttl: int,
                       return_rank: bool = False):
    """``bfs_tree_csr`` for MANY origins in one sweep.

    Returns (parent, depth, reached) each shaped (len(origins), n), row o
    bit-for-bit equal to ``bfs_tree_csr(indptr, indices, origins[o],
    ttl)``.  All origins advance level-synchronously; per-origin
    first-touch tie-breaks are preserved because candidate positions are
    only compared within the same (origin, node) key and the flattened
    frontier keeps every origin's discovery order as a subsequence.
    ``return_rank=True`` appends the per-origin within-level discovery
    ranks, row-for-row equal to the single-origin ones.
    """
    n = len(indptr) - 1
    S = len(origins)
    parent = -np.ones((S, n), dtype=np.int64)
    depth = -np.ones((S, n), dtype=np.int64)
    dflat = depth.reshape(-1)            # flat views: 1-d gathers are
    pflat = parent.reshape(-1)           # far cheaper than 2-d fancy ones
    rank = kflat = None
    if return_rank:
        rank = -np.ones((S, n), dtype=np.float64)
        kflat = rank.reshape(-1)
    ar = np.arange(S)
    depth[ar, origins] = 0
    if rank is not None:
        rank[ar, origins] = 0.0
    fr_org = ar.copy()
    fr_node = np.asarray(origins, dtype=np.int64).copy()
    # int32 sort keys radix-sort when the (origin, node) space fits
    kdt = np.int32 if S * n < 2**31 else np.int64
    lvl = 0
    while len(fr_node) and lvl < ttl:
        starts = indptr[fr_node]
        counts = indptr[fr_node + 1] - starts
        total = int(counts.sum())
        if total == 0:
            break
        offs = np.repeat(np.cumsum(counts) - counts, counts)
        pos_in_row = np.arange(total, dtype=np.int64) - offs
        cand = indices[np.repeat(starts, counts) + pos_in_row]
        src = np.repeat(fr_node, counts)
        org = np.repeat(fr_org, counts)
        keyall = org * n + cand
        new = dflat[keyall] < 0
        key = keyall[new].astype(kdt)
        if len(key) == 0:
            break
        pos = np.flatnonzero(new)
        # grouped first-touch: stable (radix) sort by key keeps
        # candidate positions ascending within each (origin, node)
        # group, so the group leader IS the minimum position —
        # bit-identical to a minimum-reduce, without its scatter cost
        order = np.argsort(key, kind="stable")
        ks = key[order]
        lead = np.empty(len(ks), bool)
        lead[0] = True
        np.not_equal(ks[1:], ks[:-1], out=lead[1:])
        fpos = pos[order[lead]]          # min position per distinct key
        # positions are distinct, so the stable (radix) sort is exact
        dord = np.argsort(fpos.astype(kdt) if total < 2**31 else fpos,
                          kind="stable") # global discovery order
        okey = ks[lead][dord].astype(np.int64)
        pflat[okey] = src[fpos[dord]]
        dflat[okey] = lvl + 1
        if kflat is not None:
            # per-origin within-level rank: stable sort by origin keeps
            # the global discovery order inside each origin's group
            uorg = okey // n
            o2 = np.argsort(uorg, kind="stable")
            grp = uorg[o2]
            within = (np.arange(len(grp), dtype=np.int64)
                      - np.searchsorted(grp, grp))
            kflat[okey[o2]] = within.astype(np.float64)
        fr_org, fr_node = okey // n, okey % n
        lvl += 1
    if return_rank:
        return parent, depth, depth >= 0, rank
    return parent, depth, depth >= 0


def bfs_tree(top: Topology, origin: int, ttl: int):
    """(parent, depth, reached): the implicit spanning tree of the flood.

    parent[origin] = -1; unreached peers have depth = -1.
    """
    n = top.n
    parent = -np.ones(n, dtype=np.int64)
    depth = -np.ones(n, dtype=np.int64)
    depth[origin] = 0
    frontier = [origin]
    lvl = 0
    while frontier and lvl < ttl:
        nxt = []
        for u in frontier:
            for v in top.neighbors[u]:
                if depth[v] < 0:
                    depth[v] = lvl + 1
                    parent[v] = u
                    nxt.append(int(v))
        frontier = nxt
        lvl += 1
    return parent, depth, depth >= 0


def eccentricity_ttl(top: Topology, origin: int) -> int:
    """Smallest TTL reaching every peer (paper: TTL=12 reaches 10k)."""
    _, depth, _ = bfs_tree(top, origin, top.n)
    return int(depth.max())
