"""Message / byte / time / accuracy accounting (paper §3.2, §5)."""
from __future__ import annotations

import dataclasses


# paper §3.2: L = 10 B per couple (4 B score + 6 B address)
ENTRY_BYTES_PAPER = 10
QUERY_BYTES = 100            # forward message payload (Q + QID + TTL + addr)


@dataclasses.dataclass
class QueryMetrics:
    algorithm: str = "fd"
    n_reached: int = 0
    n_edges_pq: int = 0
    avg_degree: float = 0.0

    m_fw: int = 0            # forward messages
    m_bw: int = 0            # backward messages
    m_rt: int = 0            # retrieve messages (requests + returns)
    b_fw: int = 0            # forward bytes
    b_bw: int = 0            # backward bytes
    b_rt: int = 0            # retrieve bytes (incl. data items)

    response_time_s: float = 0.0
    accuracy: float = 1.0    # ac_Q = |T_Q ∩ T_r| / |T_Q|

    @property
    def total_messages(self) -> int:
        return self.m_fw + self.m_bw + self.m_rt

    @property
    def total_bytes(self) -> int:
        return self.b_fw + self.b_bw + self.b_rt

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["total_messages"] = self.total_messages
        d["total_bytes"] = self.total_bytes
        return d
