"""Message / byte / time / accuracy accounting (paper §3.2, §5)."""
from __future__ import annotations

import dataclasses

import numpy as np


# paper §3.2: L = 10 B per couple (4 B score + 6 B address)
ENTRY_BYTES_PAPER = 10
QUERY_BYTES = 100            # forward message payload (Q + QID + TTL + addr)


@dataclasses.dataclass
class QueryMetrics:
    algorithm: str = "fd"
    n_reached: int = 0
    n_edges_pq: int = 0
    avg_degree: float = 0.0

    m_fw: int = 0            # forward messages
    m_bw: int = 0            # backward messages
    m_rt: int = 0            # retrieve messages (requests + returns)
    b_fw: int = 0            # forward bytes
    b_bw: int = 0            # backward bytes
    b_rt: int = 0            # retrieve bytes (incl. data items)

    response_time_s: float = 0.0
    accuracy: float = 1.0    # ac_Q = |T_Q ∩ T_r| / |T_Q|

    @property
    def total_messages(self) -> int:
        return self.m_fw + self.m_bw + self.m_rt

    @property
    def total_bytes(self) -> int:
        return self.b_fw + self.b_bw + self.b_rt

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["total_messages"] = self.total_messages
        d["total_bytes"] = self.total_bytes
        return d


_INT_FIELDS = ("n_reached", "n_edges_pq", "m_fw", "m_bw", "m_rt",
               "b_fw", "b_bw", "b_rt")
_FLOAT_FIELDS = ("avg_degree", "response_time_s", "accuracy")


@dataclasses.dataclass
class BatchMetrics:
    """Per-entry metrics of a ``run_queries`` batch.

    Every array is shaped (n_queries, n_trials); entry (q, t) holds
    exactly what ``run_query`` would report for origin q's t-th trial
    seed — ``query_metrics(q, t)`` reconstructs the scalar dataclass
    bit-for-bit.
    """
    algorithm: str
    n_queries: int
    n_trials: int
    n_reached: np.ndarray
    n_edges_pq: np.ndarray
    avg_degree: np.ndarray
    m_fw: np.ndarray
    m_bw: np.ndarray
    m_rt: np.ndarray
    b_fw: np.ndarray
    b_bw: np.ndarray
    b_rt: np.ndarray
    response_time_s: np.ndarray
    accuracy: np.ndarray

    @classmethod
    def empty(cls, algorithm: str, n_queries: int,
              n_trials: int) -> "BatchMetrics":
        shape = (n_queries, n_trials)
        kw = {f: np.zeros(shape, np.int64) for f in _INT_FIELDS}
        kw.update({f: np.zeros(shape, np.float64) for f in _FLOAT_FIELDS})
        return cls(algorithm=algorithm, n_queries=n_queries,
                   n_trials=n_trials, **kw)

    @property
    def total_messages(self) -> np.ndarray:
        return self.m_fw + self.m_bw + self.m_rt

    @property
    def total_bytes(self) -> np.ndarray:
        return self.b_fw + self.b_bw + self.b_rt

    def query_metrics(self, q: int, t: int = 0) -> QueryMetrics:
        return QueryMetrics(
            algorithm=self.algorithm,
            n_reached=int(self.n_reached[q, t]),
            n_edges_pq=int(self.n_edges_pq[q, t]),
            avg_degree=float(self.avg_degree[q, t]),
            m_fw=int(self.m_fw[q, t]), m_bw=int(self.m_bw[q, t]),
            m_rt=int(self.m_rt[q, t]),
            b_fw=int(self.b_fw[q, t]), b_bw=int(self.b_bw[q, t]),
            b_rt=int(self.b_rt[q, t]),
            response_time_s=float(self.response_time_s[q, t]),
            accuracy=float(self.accuracy[q, t]))

    def summary(self) -> dict:
        """Workload-level aggregates (means over the whole batch)."""
        out = {"algorithm": self.algorithm, "n_queries": self.n_queries,
               "n_trials": self.n_trials}
        for f in _INT_FIELDS + _FLOAT_FIELDS:
            out[f"mean_{f}"] = float(getattr(self, f).mean())
        out["mean_total_bytes"] = float(self.total_bytes.mean())
        out["mean_total_messages"] = float(self.total_messages.mean())
        return out
