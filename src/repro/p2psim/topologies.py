"""BRITE-style topology suite behind a registry (paper §5.1).

The paper validated FD "using the BRITE topology generator and
SimJava", but flat BA / Waxman overlays cover only a corner of what
BRITE models.  This module grows the repro's scenario diversity to the
families the topology-generation and P2P-search literature actually
distinguishes — power-law vs. random vs. hierarchical shapes trade
result quality against traffic very differently (see the Survey of
Search and Replication Schemes in Unstructured P2P Networks) — behind
a **registry** mirroring the ``Policy`` registry in
``repro.engine.api``:

  * ``hierarchical``   — BRITE top-down two-level: an AS-level Waxman
    graph over AS centers, a router-level BA subgraph per AS placed
    around its center, stitched by gateway edges (one per AS-level
    edge).  Intra-AS links are short, inter-AS links long — the regime
    BRITE's hierarchical mode exists to produce;
  * ``gnutella``       — power-law BA core with uniform edge rewiring:
    the measured Gnutella shape (heavy-tailed degrees plus shortcut
    randomness from peers re-connecting through host caches);
  * ``small-world``    — Watts–Strogatz ring lattice with rewiring
    (high clustering, log diameter);
  * ``random-regular`` — union of d/2 random Hamiltonian cycles: an
    exactly d-regular connected graph, the degree-homogeneous control
    case;
  * plus the flat ``ba`` / ``waxman`` generators from
    :mod:`repro.p2psim.graph`.

Every generator here returns a :class:`~repro.p2psim.graph.Topology`
carrying per-node plane ``coords`` (flat BA excepted — it has no
natural embedding), which enable BRITE's distance-proportional
per-edge latency model: ``SimParams(latency_model="edge")`` makes
every link's latency ``lat_base_s + lat_scale_s * euclidean_distance``
instead of the i.i.d. N(200 ms, var) draw.  See
``docs/TOPOLOGIES.md`` for the full catalogue and
``docs/ARCHITECTURE.md`` for how the latencies thread through the
engine backends bit-exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.p2psim.graph import (Topology, _ba_adj, _components,
                                _to_topology, _waxman_adj,
                                barabasi_albert, waxman)


@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """One named topology family: builder + defaults + provenance.

    ``regime`` names which paper / BRITE regime the family models —
    surfaced by ``docs/TOPOLOGIES.md`` and the README topology table.
    """

    name: str
    builder: Callable[..., Topology]
    regime: str
    defaults: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def build(self, n: int, seed: int = 0, **overrides) -> Topology:
        """Build an ``n``-peer instance (defaults merged w/ overrides)."""
        kw = {**self.defaults, **overrides}
        return self.builder(n, seed=seed, **kw)


_REGISTRY: Dict[str, TopologySpec] = {}


def register_topology(spec: TopologySpec, *,
                      overwrite: bool = False) -> TopologySpec:
    """Add a topology family to the global registry (error on duplicate
    names unless ``overwrite``)."""
    if not overwrite and spec.name in _REGISTRY:
        raise ValueError(f"topology {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get_topology(spec) -> TopologySpec:
    """Resolve a registered family name; a ``TopologySpec`` passes
    through."""
    if isinstance(spec, TopologySpec):
        return spec
    try:
        return _REGISTRY[spec]
    except KeyError:
        raise KeyError(f"unknown topology {spec!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def available_topologies() -> Tuple[str, ...]:
    """Registered family names, in registration order."""
    return tuple(_REGISTRY)


def build_topology(name, n: int, seed: int = 0, **overrides) -> Topology:
    """Build an ``n``-peer instance of a registered family."""
    return get_topology(name).build(n, seed=seed, **overrides)


# --------------------------------------------------------------------------
# generators
# --------------------------------------------------------------------------

def _bridge_chain(adj: List[set]) -> None:
    """Connect components by chaining one representative per component.

    Used by generators whose rewiring step can (rarely) disconnect the
    graph; adds ``n_components - 1`` edges, never nodes.
    """
    comp = _components(adj)
    k = int(comp.max()) + 1
    if k <= 1:
        return
    reps = [int(np.flatnonzero(comp == c)[0]) for c in range(k)]
    for a, b in zip(reps, reps[1:]):
        adj[a].add(b)
        adj[b].add(a)


def hierarchical(n: int, n_as: Optional[int] = None, m_router: int = 2,
                 as_alpha: float = 0.4, as_beta: float = 0.4,
                 as_avg_degree: float = 3.0, gw_per_edge: int = 1,
                 spread: float = 0.06, seed: int = 0) -> Topology:
    """BRITE-style two-level top-down hierarchical topology.

    ``n_as`` AS centers (default ``round(n ** (1/3))``, so 100k peers
    get ~46 ASes) are placed uniformly in the unit square and wired by
    an AS-level Waxman graph (``as_alpha`` / ``as_beta`` /
    ``as_avg_degree``, nearest-pair bridged to one component).  Each AS
    holds a router-level BA subgraph (``m_router``) whose nodes sit
    within ``spread`` of the AS center, so intra-AS links are short and
    inter-AS links long — exactly the latency structure BRITE's
    hierarchical mode produces.  Every AS-level edge is realized by
    ``gw_per_edge`` gateway edges between uniformly chosen routers of
    the two ASes.

    Connected by construction: each BA subgraph is connected, the AS
    graph is connected, and every AS edge contributes at least one
    gateway edge.
    """
    rng = np.random.default_rng(seed)
    if n_as is None:
        n_as = max(2, int(round(n ** (1.0 / 3.0))))
    n_as = max(1, min(n_as, n))
    centers = rng.random((n_as, 2))
    if n_as > 1:
        as_adj = _waxman_adj(centers, as_alpha, as_beta,
                             min(as_avg_degree, n_as - 1), rng)
    else:
        as_adj = [set()]
    sizes = np.full(n_as, n // n_as, dtype=np.int64)
    sizes[: n % n_as] += 1
    offs = np.concatenate([[0], np.cumsum(sizes)])
    adj: List[set] = [set() for _ in range(n)]
    coords = np.empty((n, 2))
    for a in range(n_as):
        sz = int(sizes[a])
        sub = _ba_adj(sz, min(m_router, max(sz - 1, 0)), rng)
        for u, nbrs in enumerate(sub):
            gu = int(offs[a]) + u
            for v in nbrs:
                adj[gu].add(int(offs[a]) + int(v))
        coords[offs[a]:offs[a + 1]] = (
            centers[a] + (rng.random((sz, 2)) - 0.5) * spread)
    np.clip(coords, 0.0, 1.0, out=coords)
    for a in range(n_as):
        for b in as_adj[a]:
            if a < b:
                for _ in range(gw_per_edge):
                    u = int(offs[a]) + int(rng.integers(sizes[a]))
                    v = int(offs[b]) + int(rng.integers(sizes[b]))
                    adj[u].add(v)
                    adj[v].add(u)
    return _to_topology(adj, "hierarchical", coords=coords)


def gnutella(n: int, m: int = 2, rewire_p: float = 0.10,
             seed: int = 0) -> Topology:
    """Gnutella-like overlay: BA power-law core + uniform rewiring.

    Each BA edge is, with probability ``rewire_p``, re-pointed from its
    higher endpoint to a uniformly random peer — the shortcut noise
    measured Gnutella snapshots show on top of the preferential-
    attachment backbone.  Rewires that would create a self-loop or a
    duplicate edge keep the original edge; components (rewiring can
    rarely split one off) are chain-bridged.  Coordinates are uniform
    in the unit square.
    """
    rng = np.random.default_rng(seed)
    adj = _ba_adj(n, m, rng)
    coords = rng.random((n, 2))
    edges = [(u, int(v)) for u in range(n) for v in adj[u] if u < v]
    flips = rng.random(len(edges)) < rewire_p
    targets = rng.integers(0, n, len(edges))
    for (u, v), flip, w in zip(edges, flips, targets):
        w = int(w)
        if not flip or w == u or w in adj[u] or v not in adj[u]:
            continue
        adj[u].discard(v)
        adj[v].discard(u)
        adj[u].add(w)
        adj[w].add(u)
    _bridge_chain(adj)
    return _to_topology(adj, "gnutella", coords=coords)


def small_world(n: int, k_ring: int = 4, rewire_p: float = 0.10,
                seed: int = 0) -> Topology:
    """Watts–Strogatz small world: ring lattice + random rewiring.

    Every node links to its ``k_ring // 2`` nearest neighbors on each
    side of a ring; each clockwise lattice edge is rewired to a uniform
    target with probability ``rewire_p`` (self-loops/duplicates keep
    the lattice edge).  Nodes are embedded on a circle, so the per-edge
    latency model sees short lattice hops and long chords.  Components
    are chain-bridged (rewiring can rarely disconnect).
    """
    rng = np.random.default_rng(seed)
    half = max(1, k_ring // 2)
    adj: List[set] = [set() for _ in range(n)]
    for j in range(1, half + 1):
        for u in range(n):
            v = (u + j) % n
            if u != v:
                adj[u].add(v)
                adj[v].add(u)
    for j in range(1, half + 1):
        flips = rng.random(n) < rewire_p
        targets = rng.integers(0, n, n)
        for u in np.flatnonzero(flips):
            u = int(u)
            v = (u + j) % n
            w = int(targets[u])
            if w == u or w in adj[u] or v not in adj[u]:
                continue
            adj[u].discard(v)
            adj[v].discard(u)
            adj[u].add(w)
            adj[w].add(u)
    _bridge_chain(adj)
    theta = 2.0 * np.pi * np.arange(n) / max(n, 1)
    coords = 0.5 + 0.48 * np.stack([np.cos(theta), np.sin(theta)], axis=1)
    return _to_topology(adj, "small-world", coords=coords)


def random_regular(n: int, d: int = 4, seed: int = 0,
                   max_tries: int = 100) -> Topology:
    """Random d-regular graph as a union of d/2 Hamiltonian cycles.

    Each cycle is a uniform permutation of the peers; a cycle that
    would duplicate an existing edge is redrawn (at most ``max_tries``
    times — collisions are O(1/n) rare).  Exactly d-regular, connected
    by construction (cycle 1 alone is Hamiltonian), no self-loops or
    multi-edges.  ``d`` must be even; coordinates are uniform.
    """
    if d < 2 or d % 2:
        raise ValueError(f"d must be even and >= 2, got {d}")
    if n <= d:
        raise ValueError(f"need n > d, got n={n}, d={d}")
    rng = np.random.default_rng(seed)
    adj: List[set] = [set() for _ in range(n)]
    for _ in range(d // 2):
        for _ in range(max_tries):
            perm = rng.permutation(n)
            es = [(int(perm[i]), int(perm[(i + 1) % n]))
                  for i in range(n)]
            if all(v not in adj[u] for u, v in es):
                break
        else:
            raise RuntimeError(
                f"no edge-disjoint Hamiltonian cycle after {max_tries} "
                f"draws (n={n}, d={d})")
        for u, v in es:
            adj[u].add(v)
            adj[v].add(u)
    coords = rng.random((n, 2))
    return _to_topology(adj, "random-regular", coords=coords)


# The family, named once (BRITE models + the shapes of the survey
# literature).  ``waxman`` is O(n^2) in memory — flat-overlay scale.
register_topology(TopologySpec(
    "ba", barabasi_albert,
    regime="BRITE 'BA' flat router model — Gnutella-shaped power law, "
           "d(G) ~ 2m (paper §5.1; no embedding, i.i.d. latency only)",
    defaults={"m": 2}))
register_topology(TopologySpec(
    "waxman", waxman,
    regime="BRITE 'RTWaxman' flat random-geometric model (O(n^2) "
           "build — flat-overlay scale)",
    defaults={"alpha": 0.15, "beta": 0.2, "avg_degree": 4.0}))
register_topology(TopologySpec(
    "hierarchical", hierarchical,
    regime="BRITE top-down hierarchical: AS-level Waxman over router-"
           "level BA, gateway-stitched; short intra-AS / long inter-AS "
           "links",
    defaults={"m_router": 2}))
register_topology(TopologySpec(
    "gnutella", gnutella,
    regime="measured Gnutella: power-law core + host-cache shortcut "
           "rewiring",
    defaults={"m": 2, "rewire_p": 0.10}))
register_topology(TopologySpec(
    "small-world", small_world,
    regime="Watts-Strogatz ring lattice + rewiring: high clustering, "
           "log diameter",
    defaults={"k_ring": 4, "rewire_p": 0.10}))
register_topology(TopologySpec(
    "random-regular", random_regular,
    regime="union of d/2 random Hamiltonian cycles: exactly d-regular "
           "degree-homogeneous control case",
    defaults={"d": 4}))
