"""Vectorized simulator of FD over an unstructured overlay (paper §3–§5).

Faithful to the paper's four phases with the Appendix-A wait-time model:

  * query forward — TTL flood; FD-Basic / Strategy 1 (randomized λ, each
    edge once w.h.p.) / Strategy 1+2 (piggybacked neighbor lists);
  * local execution — per-peer top-k of n_i ∈ [1000, 20000] uniform
    scores, sampled exactly via order statistics (no tuple
    materialization);
  * merge-and-backward — bottom-up k-list merge along the implicit
    spanning tree; a peer sends at its wait deadline or when all
    children reported, whichever is first; late lists are DROPPED by
    FD-Basic and bubbled as *urgent* lists by FD-Dynamic (§4.1);
  * data retrieval — direct fetch from the ≤ k winning owners.

Baselines (§5.1): CN (peers ship k data items to the originator),
CN* (peers ship k-lists to the originator); both compete for the
originator's bandwidth — the paper's central-node bottleneck.

Churn (§4/§5.4): exponential residual lifetimes; dead parents lose
subtrees in FD-Basic, FD-Dynamic reroutes via non-child neighbors or
directly to the originator.
"""
from __future__ import annotations

import copy
import dataclasses
import math
import os
import warnings
from typing import Optional

import numpy as np

from repro.p2psim.graph import Topology, as_csr, bfs_tree, bfs_tree_csr
from repro.p2psim.metrics import (ENTRY_BYTES_PAPER, QUERY_BYTES,
                                  BatchMetrics, QueryMetrics)


@dataclasses.dataclass
class SimParams:
    """Table 1 of the paper."""
    k: int = 20
    ttl: int = 0                    # 0 -> auto (reach everyone)
    latency_mean_s: float = 0.200   # N(200 ms, var 100 ms^2)
    latency_var: float = 0.100 ** 2
    bw_mean_Bps: float = 56_000.0 / 8.0      # 56 kbps
    bw_var: float = (32_000.0 / 8.0) ** 2
    tuples_lo: int = 1000
    tuples_hi: int = 20000
    item_mean_B: float = 1024.0     # result data item ~ N(1 KB, ...)
    item_std_B: float = 256.0
    exec_s_per_tuple: float = 2e-5  # T_exec(Q) ~ 0.02..0.4 s
    merge_s: float = 0.002          # T_Merge(k)
    lam_max_s: float = 0.05         # Strategy-1 random wait λ
    request_B: int = 50
    # Appendix-A wait-time cost parameters (MAX estimates)
    t_qsnd_s: float = 0.5
    t_exec_max_s: float = 0.5
    t_slsnd_s: float = 0.5
    seed: int = 0
    # "iid"  — per-link latency ~ N(latency_mean_s, latency_var), the
    #          paper's Table-1 draw (default; RNG streams unchanged);
    # "edge" — per-edge latency from the topology's plane embedding
    #          (BRITE's distance-proportional delay, see
    #          Topology.pair_latency); needs a coordinate-carrying
    #          generator from repro.p2psim.topologies.  Bandwidths stay
    #          i.i.d. draws in both models.
    latency_model: str = "iid"
    # Replication (survey-motivated churn mitigation): every peer's
    # top-k items live on `replication_factor` additional peers, chosen
    # by the registered `replication_placement` policy ("random" /
    # "neighbor" — see register_placement).  At the FD retrieval phase a
    # dead owner's items are fetched from its first alive replica; an
    # item is lost only when the owner AND all its replicas are gone.
    # The placement table is a deterministic property of the overlay
    # (fixed internal seed, NOT the query stream), so `=0` leaves every
    # drawn bit unchanged and the CN baselines are unaffected.
    replication_factor: int = 0
    replication_placement: str = "random"


# --------------------------------------------------------------------------
# local query execution: exact top-k order statistics of n uniforms
# --------------------------------------------------------------------------

def local_topk_scores(n_tuples: np.ndarray, k: int,
                      rng: np.random.Generator) -> np.ndarray:
    """(P, k) descending top-k of n_i U[0,1] scores, sampled exactly:
    top-1 = U^(1/n); successive gaps via the Rényi representation."""
    p = len(n_tuples)
    u = rng.random((p, k))
    out = np.empty((p, k))
    cur = np.ones(p)
    remaining = n_tuples.astype(np.float64)
    for j in range(k):
        cur = cur * u[:, j] ** (1.0 / np.maximum(remaining, 1.0))
        out[:, j] = cur
        remaining -= 1.0
    return out


def wait_time(ttl_rem: np.ndarray, p: SimParams) -> np.ndarray:
    """Appendix A formula (2)."""
    t = ttl_rem.astype(np.float64)
    return (t * p.t_qsnd_s + p.t_exec_max_s + t * p.t_slsnd_s
            + np.maximum(t - 1.0, 0.0) * p.merge_s)


def _link_time(nbytes: float, lat: np.ndarray, bw: np.ndarray) -> np.ndarray:
    return lat + nbytes / bw


def _draw_link(rng, p: SimParams, size):
    lat = np.maximum(rng.normal(p.latency_mean_s,
                                math.sqrt(p.latency_var), size), 1e-3)
    bw = np.maximum(rng.normal(p.bw_mean_Bps, math.sqrt(p.bw_var), size),
                    1_000.0)
    return lat, bw


def _draw_bw(rng, p: SimParams, size):
    """Bandwidth-only draw — the ``latency_model="edge"`` link draw.

    The latency half of ``_draw_link`` is deterministic (the embedding
    distance), so the stream advances by the bandwidth normals ONLY;
    every backend uses this same helper, which is what keeps the edge
    model's streams aligned across reference / numpy / jax.
    """
    return np.maximum(rng.normal(p.bw_mean_Bps, math.sqrt(p.bw_var), size),
                      1_000.0)


def _latency_mode(top: Topology, p: SimParams) -> bool:
    """Validate ``p.latency_model`` against ``top``; True = edge mode."""
    if p.latency_model not in ("iid", "edge"):
        raise ValueError(
            f"latency_model must be 'iid' or 'edge', "
            f"got {p.latency_model!r}")
    if p.latency_model == "edge" and top.coords is None:
        raise ValueError(
            f"latency_model='edge' needs node coordinates; topology "
            f"{top.kind!r} has none (use a coordinate-carrying "
            "generator from repro.p2psim.topologies)")
    return p.latency_model == "edge"


def _tree_edge_latency(top: Topology, parent: np.ndarray) -> np.ndarray:
    """(n,) latency of each node's tree edge v <-> parent(v) from the
    embedding (positions without a parent hold the floor value — never
    read by the sweeps)."""
    safe = np.maximum(parent, 0)
    lat = top.pair_latency(np.arange(top.n), safe)
    return np.where(parent >= 0, lat, top.lat_base_s)


# --------------------------------------------------------------------------
# replication: placement registry + retrieval-fallback model
# --------------------------------------------------------------------------

# placement(indptr, indices, r, rng) -> (n, r) replica peer ids (-1 pad)
_PLACEMENTS: dict = {}

# the placement table is a property of the NETWORK, not of any query:
# it is drawn from this fixed internal stream so every backend — and
# every per-entry seed — sees the same table, and the query RNG streams
# never move
_PLACEMENT_STREAM = 0x5EED_0FAB


def register_placement(name: str, fn) -> None:
    """Register a replica placement policy under ``name``."""
    _PLACEMENTS[name] = fn


def get_placement(name: str):
    """Look up a registered replica placement policy by name."""
    try:
        return _PLACEMENTS[name]
    except KeyError:
        raise KeyError(
            f"unknown replication placement {name!r}; registered: "
            f"{available_placements()}") from None


def available_placements() -> tuple:
    """Registered placement-policy names, sorted."""
    return tuple(sorted(_PLACEMENTS))


def _place_random(indptr, indices, r: int, rng) -> np.ndarray:
    """r uniform peers per owner (excluding the owner itself)."""
    n = len(indptr) - 1
    if n <= 1:
        return np.full((n, r), -1, np.int64)
    tab = np.empty((n, r), np.int64)
    for j in range(r):
        cand = rng.integers(0, n - 1, n)
        cand += cand >= np.arange(n)         # skip the owner's own id
        tab[:, j] = cand
    return tab


def _place_neighbor(indptr, indices, r: int, rng) -> np.ndarray:
    """r uniform NEIGHBORS per owner (isolated owners get no replicas)."""
    n = len(indptr) - 1
    deg = np.diff(indptr)
    tab = np.full((n, r), -1, np.int64)
    for j in range(r):
        raw = rng.integers(0, 1 << 62, n)
        sel = raw % np.maximum(deg, 1)
        tab[:, j] = np.where(deg > 0, indices[indptr[:-1] + sel], -1)
    return tab


register_placement("random", _place_random)
register_placement("neighbor", _place_neighbor)


def build_replica_table(indptr, indices, r: int,
                        placement: str) -> np.ndarray:
    """(n, r) replica peer ids per owner (-1 = unfilled slot).

    Deterministic in (overlay CSR, r, placement) — the scalar
    reference and the batched engines compute it from the same CSR
    arrays, so replication never enters the cross-backend parity story
    as anything but shared input data.
    """
    rng = np.random.default_rng(_PLACEMENT_STREAM + r)
    return get_placement(placement)(indptr, indices, r, rng)


def _serving_peers(owners: np.ndarray, replicas, death_row: np.ndarray,
                   t: float) -> np.ndarray:
    """Per owner: the peer that serves its items at time ``t`` — the
    owner itself when alive, else its first alive replica, else -1
    (items lost).  ``replicas`` is the (n, r) table or None."""
    served = np.where(death_row[owners] > t, owners, -1)
    if replicas is not None and replicas.shape[1] and len(owners):
        need = served < 0
        if need.any():
            reps = replicas[owners[need]]                   # (m, r)
            ok = (reps >= 0) & (death_row[np.maximum(reps, 0)] > t)
            has = ok.any(axis=1)
            first = reps[np.arange(len(reps)), ok.argmax(axis=1)]
            served[need] = np.where(has, first, -1)
    return served


# --------------------------------------------------------------------------
# forward-phase message counting
# --------------------------------------------------------------------------

def forward_messages(top: Topology, origin: int, parent, depth, reached,
                     strategy: str, p: SimParams,
                     rng: np.random.Generator,
                     child_allowed: Optional[np.ndarray] = None) -> int:
    """Count forward messages for basic / st1 / st1+2.

    ``child_allowed``: bool (n,) — statistics-heuristic pruning: peers a
    parent refuses to forward to (their subtree never receives Q) must be
    handled by the caller re-running bfs on the pruned graph; here it only
    restricts the counting.
    """
    n = top.n
    ttl = p.ttl
    ttl_rem = ttl - depth
    if strategy == "basic":
        m = 0
        for u in range(n):
            if not reached[u] or ttl_rem[u] <= 0:
                continue
            deg = len(top.neighbors[u])
            m += deg if u == origin else deg - 1
        return m
    # strategy 1 / 1+2: randomized λ per peer; send only to neighbors not
    # yet heard from
    lam = rng.random(n) * p.lam_max_s
    t_q = np.where(depth >= 0, depth * p.t_qsnd_s, np.inf)  # coarse arrival
    send_at = t_q + lam
    m = 0
    for u in range(n):
        if not reached[u] or ttl_rem[u] <= 0:
            continue
        pu = parent[u]
        plist: set = set()
        if strategy == "st1+2" and pu >= 0:
            plist = set(int(x) for x in top.neighbors[pu])
            plist.add(int(pu))
        for v in top.neighbors[u]:
            v = int(v)
            if v == pu:
                continue
            if not reached[v]:
                m += 1          # edge to a peer beyond TTL still costs
                continue
            if strategy == "st1+2" and v in plist:
                continue        # Strategy 2: v provably has Q already
            # Strategy 1: u sends unless it heard v's copy first
            if parent[v] == u:
                m += 1          # tree edge: u is v's first sender
            elif send_at[v] < send_at[u] and (parent[u] == v
                                              or depth[v] <= depth[u]):
                # v sent earlier and u would have received it: skip
                continue
            else:
                m += 1
    return m


# --------------------------------------------------------------------------
# full query simulation
# --------------------------------------------------------------------------

def run_query_reference(top: Topology, origin: int = 0,
                        params: Optional[SimParams] = None,
                        *, algorithm: str = "fd", strategy: str = "st1+2",
                        dynamic: bool = True,
                        lifetime_mean_s: float = float("inf"),
                        child_mask: Optional[np.ndarray] = None,
                        return_state: bool = False):
    """Simulate one Top-k query — the scalar REFERENCE implementation.

    This is the executable spec the engine is held to: the unified
    ``repro.engine.SimEngine`` (and the ``run_query``/``run_queries``
    shims over it) must reproduce it bit-for-bit.  Returns QueryMetrics
    (+ state dict).

    algorithm: "fd" | "cn" | "cn_star".
    strategy (fd): "basic" | "st1" | "st1+2" (forward-phase counting).
    dynamic (fd): urgent score-lists + rerouting (§4) on/off.
    child_mask: bool (n,) — peers excluded from forwarding (statistics
    heuristic §3.3); excluded subtrees never receive Q.
    """
    p = params if params is not None else SimParams()
    edge_lat = _latency_mode(top, p)
    rng = np.random.default_rng(p.seed)
    n = top.n
    pre_bfs = None
    if p.ttl == 0:
        if child_mask is None:
            # auto TTL = eccentricity: the full-depth BFS *is* the
            # TTL-limited BFS at that TTL, so resolve and reuse in one pass
            pre_bfs = bfs_tree(top, origin, n)
            p = dataclasses.replace(p, ttl=int(pre_bfs[1].max()))
        else:
            from repro.p2psim.graph import eccentricity_ttl
            p = dataclasses.replace(p, ttl=eccentricity_ttl(top, origin))

    # ---- reach set (optionally pruned) ---------------------------------
    if child_mask is not None:
        pruned = Topology(n, [top.neighbors[u][child_mask[top.neighbors[u]]]
                              if child_mask[u] or u == origin
                              else np.array([], np.int32)
                              for u in range(n)], top.kind)
        parent, depth, reached = bfs_tree(pruned, origin, p.ttl)
        count_top = pruned
    else:
        parent, depth, reached = (pre_bfs if pre_bfs is not None
                                  else bfs_tree(top, origin, p.ttl))
        count_top = top
    idx = np.flatnonzero(reached)
    n_r = len(idx)
    ttl_rem = np.maximum(p.ttl - depth, 0)

    # ---- local data ----------------------------------------------------
    n_tuples = rng.integers(p.tuples_lo, p.tuples_hi + 1, n)
    scores = local_topk_scores(n_tuples, p.k, rng)          # (n, k)
    t_exec = n_tuples * p.exec_s_per_tuple

    # ---- per-edge link draws (tree edges) ------------------------------
    if edge_lat:
        # BRITE distance-proportional latency: deterministic per edge
        # and symmetric (one physical link), bandwidth still drawn per
        # direction in the iid draw's stream positions
        par_lat = _tree_edge_latency(top, parent)
        lat_up, bw_up = par_lat, _draw_bw(rng, p, n)   # v -> parent(v)
        lat_dn, bw_dn = par_lat, _draw_bw(rng, p, n)   # parent(v) -> v
    else:
        lat_up, bw_up = _draw_link(rng, p, n)   # v -> parent(v)
        lat_dn, bw_dn = _draw_link(rng, p, n)   # parent(v) -> v

    # query arrival times down the tree
    t_q = np.full(n, np.inf)
    t_q[origin] = 0.0
    order = idx[np.argsort(depth[idx])]
    for v in order:
        if v == origin:
            continue
        t_q[v] = t_q[parent[v]] + _link_time(QUERY_BYTES, lat_dn[v], bw_dn[v])
    t_ex_done = t_q + t_exec

    # ---- churn ----------------------------------------------------------
    if math.isinf(lifetime_mean_s):
        death = np.full(n, np.inf)
    else:
        death = rng.exponential(lifetime_mean_s, n)
        death[origin] = np.inf

    met = QueryMetrics(algorithm=algorithm)
    met.n_reached = n_r
    sub = set(int(i) for i in idx)
    met.n_edges_pq = sum(
        1 for u in idx for v in top.neighbors[u] if u < v and int(v) in sub)
    met.avg_degree = float(np.mean([len(top.neighbors[u]) for u in idx]))

    list_bytes = p.k * ENTRY_BYTES_PAPER
    item_sizes = np.maximum(
        rng.normal(p.item_mean_B, p.item_std_B, (n, p.k)), 64.0)

    # ---- CN / CN* baselines --------------------------------------------
    if algorithm in ("cn", "cn_star"):
        if edge_lat:
            # direct originator links: embedding distance origin -> v
            lat_o = top.pair_latency(origin, np.arange(n))
            bw_o = _draw_bw(rng, p, n)
        else:
            lat_o, bw_o = _draw_link(rng, p, n)
        per_peer = (item_sizes[:, :p.k].sum(1) if algorithm == "cn"
                    else np.full(n, float(list_bytes)))
        alive = death > t_ex_done
        senders = idx[alive[idx]]
        senders = senders[senders != origin]
        met.m_fw = forward_messages(count_top, origin, parent, depth,
                                    reached, "basic", p, rng)
        met.b_fw = met.m_fw * QUERY_BYTES
        met.m_bw = len(senders)
        met.b_bw = int(per_peer[senders].sum())
        # originator bandwidth contention: serialized arrival
        own_bw = max(p.bw_mean_Bps, 1.0)
        t_arrive = t_ex_done[senders] + lat_o[senders]
        t_resp = (np.max(t_arrive) if len(senders) else 0.0) \
            + per_peer[senders].sum() / own_bw
        if algorithm == "cn_star":
            # retrieval of actual items still needed
            true_full = np.full((n, p.k), -np.inf)
            true_full[idx] = scores[idx]
            flat = true_full.reshape(-1)
            top_idx = np.argpartition(flat, -p.k)[-p.k:]
            owners = np.unique(top_idx // p.k)
            met.m_rt = 2 * len(owners)
            met.b_rt = int(met.m_rt / 2 * p.request_B
                           + item_sizes.reshape(-1)[top_idx].sum())
            t_resp += 2 * p.latency_mean_s + met.b_rt / own_bw
        met.response_time_s = float(t_resp)
        delivered = np.zeros(n, bool)
        delivered[senders] = True
        delivered[origin] = True
        met.accuracy = _accuracy(scores, idx, delivered, p.k)
        return (met, None) if not return_state else (met, {
            "parent": parent, "depth": depth, "reached": reached})

    # ---- FD: merge-and-backward ----------------------------------------
    met.m_fw = forward_messages(count_top, origin, parent, depth, reached,
                                strategy, p, rng)
    met.b_fw = met.m_fw * QUERY_BYTES

    deadline = t_q + wait_time(ttl_rem, p)
    children: list = [[] for _ in range(n)]
    for v in idx:
        if parent[v] >= 0:
            children[parent[v]].append(int(v))

    # bottom-up: actual send time, delivered lists, merged content
    send_t = np.zeros(n)
    merged_scores = [None] * n       # (k,) arrays
    merged_owner = [None] * n
    delivered = np.zeros(n, bool)    # peer's own top-k reached its parent
    late_urgent: list = []           # (arrival_at_origin_estimate, peer)

    for v in order[::-1]:
        ch = children[v]
        arrivals = []
        for c in ch:
            a = send_t[c] + _link_time(list_bytes, lat_up[c], bw_up[c])
            arrivals.append((a, c))
        own_ready = t_ex_done[v]
        all_in = max([a for a, _ in arrivals], default=0.0)
        s = min(max(own_ready, all_in), max(deadline[v], own_ready))
        if death[v] < s:
            # peer left before sending: its subtree's merged list is lost
            # unless dynamic rerouting saves the CHILDREN's lists (they
            # reroute around the dead parent, §4.2)
            send_t[v] = np.inf
            merged_scores[v] = None
            continue
        send_t[v] = s
        # merge own + children lists that arrived in time (or urgent)
        mats = [scores[v]]
        owners = [np.full(p.k, v, dtype=np.int64)]
        for a, c in arrivals:
            if merged_scores[c] is None:
                # dead child subtree
                if dynamic:
                    for cc in children[c]:
                        if merged_scores[cc] is not None and \
                                send_t[cc] < np.inf:
                            mats.append(merged_scores[cc])
                            owners.append(merged_owner[cc])
                            met.m_bw += 1
                            met.b_bw += list_bytes
                continue
            if a <= s:
                mats.append(merged_scores[c])
                owners.append(merged_owner[c])
            else:
                if dynamic:
                    # urgent list: bubbles without wait; reaches origin
                    hops = depth[v]
                    eta = a + hops * (p.latency_mean_s
                                      + list_bytes / p.bw_mean_Bps)
                    late_urgent.append((eta, c))
                    met.m_bw += int(hops)
                    met.b_bw += int(hops) * list_bytes
        allm = np.concatenate(mats)
        allo = np.concatenate(owners)
        sel = np.argsort(allm)[::-1][:p.k]
        merged_scores[v] = allm[sel]
        merged_owner[v] = allo[sel]
        if v != origin:
            met.m_bw += 1
            met.b_bw += list_bytes

    # urgent lists accepted if they arrive before retrieval starts
    t_merge_done = send_t[origin] + p.merge_s
    extra = []
    for eta, c in late_urgent:
        if eta <= t_merge_done and merged_scores[c] is not None:
            extra.append((merged_scores[c], merged_owner[c]))
    if extra and merged_scores[origin] is not None:
        allm = np.concatenate([merged_scores[origin]]
                              + [e[0] for e in extra])
        allo = np.concatenate([merged_owner[origin]]
                              + [e[1] for e in extra])
        sel = np.argsort(allm)[::-1][:p.k]
        merged_scores[origin] = allm[sel]
        merged_owner[origin] = allo[sel]

    # ---- data retrieval --------------------------------------------------
    # a dead owner's items are fetched from its first alive replica
    # (replication_factor > 0); `served[i]` is the peer that serves
    # final owner i's items, or -1 when owner and all replicas are gone
    final_owners = np.unique(merged_owner[origin])
    replicas = None
    if p.replication_factor > 0:
        ip_, ix_ = as_csr(top)
        replicas = build_replica_table(ip_, ix_, p.replication_factor,
                                       p.replication_placement)
    served = _serving_peers(final_owners, replicas, death, t_merge_done)
    srv = served >= 0
    met.m_rt = 2 * int(srv.sum())
    if edge_lat:
        lat_o = top.pair_latency(origin,
                                 np.where(srv, served, final_owners))
        bw_o = _draw_bw(rng, p, len(final_owners))
    else:
        lat_o, bw_o = _draw_link(rng, p, len(final_owners))
    per_owner_counts = np.array(
        [(merged_owner[origin] == o).sum() for o in final_owners])
    fetch_bytes = per_owner_counts * p.item_mean_B
    met.b_rt = int(srv.sum() * p.request_B + fetch_bytes[srv].sum())
    t_fetch = (2 * lat_o + (p.request_B + fetch_bytes) / bw_o)
    t_fetch = t_fetch[srv]
    met.response_time_s = float(
        t_merge_done + (t_fetch.max() if len(t_fetch) else 0.0))

    # ---- accuracy ---------------------------------------------------------
    # delivered set: owners present in the final list are by construction
    # delivered; accuracy compares final list vs true top-k of reached set
    true_scores = scores[idx].reshape(-1)
    top_true = np.sort(true_scores)[::-1][:p.k]
    got = np.sort(merged_scores[origin])[::-1]
    # intersection by value (scores a.s. distinct)
    inter = np.intersect1d(top_true, got).size
    # retrieval failures (owner + every replica dead) lose their items
    lost_owned = np.isin(merged_owner[origin], final_owners[~srv])
    inter = max(0, inter - int(np.isin(
        merged_scores[origin][lost_owned], top_true).sum()))
    met.accuracy = inter / p.k

    state = {"parent": parent, "depth": depth, "reached": reached,
             "merged_scores": merged_scores, "merged_owner": merged_owner,
             "children": children, "scores": scores}
    return (met, state) if return_state else (met, None)


def _accuracy(scores, idx, delivered, k) -> float:
    true_scores = scores[idx].reshape(-1)
    top_true = np.sort(true_scores)[::-1][:k]
    deliv_idx = idx[delivered[idx]]
    if len(deliv_idx) == 0:
        return 0.0
    got = np.sort(scores[deliv_idx].reshape(-1))[::-1][:k]
    return float(np.intersect1d(top_true, got).size) / k


def _legacy_gate(message: str) -> None:
    """Retired-shim gate: raise, unless ``REPRO_LEGACY_API=1`` opts back
    into the old (warn-and-delegate) behavior for one more release."""
    if os.environ.get("REPRO_LEGACY_API") == "1":
        warnings.warn(message, DeprecationWarning, stacklevel=3)
        return
    raise RuntimeError(
        f"{message} (the legacy entrypoints are retired; set "
        "REPRO_LEGACY_API=1 to temporarily re-enable them)")


def run_query(top: Topology, origin: int = 0,
              params: Optional[SimParams] = None,
              *, algorithm: str = "fd", strategy: str = "st1+2",
              dynamic: bool = True, lifetime_mean_s: float = float("inf"),
              child_mask: Optional[np.ndarray] = None,
              return_state: bool = False):
    """Simulate one Top-k query — thin shim over ``repro.engine``.

    Kept for backward compatibility; ``repro.engine.SimEngine`` is the
    entrypoint (and amortizes its compiled ``NetworkPlan`` across calls,
    which this per-call shim cannot).  Bit-for-bit equal to
    ``run_query_reference`` — see tests/test_engine.py.  The
    ``child_mask`` / ``return_state`` variants carry per-node state the
    batch engine does not expose and run the reference directly.

    .. deprecated:: use ``repro.engine.SimEngine`` with a ``QuerySpec``
       (``SimEngine(top, params).run(QuerySpec(origins=(origin,)),
       policy)``) — see the README migration table.
    """
    _legacy_gate(
        "run_query is deprecated; use repro.engine.SimEngine with a "
        "QuerySpec: SimEngine(top, params).run(QuerySpec(origins="
        "(origin,)), policy) — see the README migration table")
    if child_mask is not None or return_state:
        return run_query_reference(
            top, origin, params, algorithm=algorithm, strategy=strategy,
            dynamic=dynamic, lifetime_mean_s=lifetime_mean_s,
            child_mask=child_mask, return_state=return_state)
    from repro.engine import QuerySpec, SimEngine, policy_from_legacy
    pol = policy_from_legacy(algorithm, strategy, dynamic, lifetime_mean_s)
    res = SimEngine(top, params).run(QuerySpec(origins=(int(origin),)), pol)
    return res.metrics.query_metrics(0, 0), None


# ==========================================================================
# batched multi-query engine
# ==========================================================================
#
# The machinery below executes a (n_queries × n_trials) batch in one
# call; ``repro.engine.SimEngine`` orchestrates it (``run_queries`` is a
# shim).  Entry (q, t) is seeded ``params.seed + q * n_trials + t`` and
# reproduces ``run_query_reference`` on that seed BIT-FOR-BIT: the
# per-entry RNG streams draw
# the same arrays in the same order, per-element float expressions are
# identical, and every reduction that crosses elements is either integer,
# a max, or a top-k selection over almost-surely-distinct values — all
# order-independent — so replacing the per-peer Python loops with array
# ops over (trials × peers × edges) changes nothing but the wall-clock.
#
# Work is split into three tiers:
#   * per-topology   — CSR adjacency, directed edge arrays (once);
#   * per-origin     — BFS tree, levels, children CSR, forward-phase
#                      static edge masks (cached across trials);
#   * per-trial      — RNG draws + vectorized wait/merge/churn sweeps,
#                      batched over all trials of an origin at once.
# Rare churn events (dead-parent reroute, urgent lists) fall back to
# small per-event loops; top-k(top-k(A) ∪ B) == top-k(A ∪ B) makes the
# post-hoc re-merge exact.


def _draw_link_batch(rngs, p: SimParams, size):
    pairs = [_draw_link(r, p, size) for r in rngs]
    return (np.stack([a for a, _ in pairs]),
            np.stack([b for _, b in pairs]))


def _draw_bw_batch(rngs, p: SimParams, size):
    return np.stack([_draw_bw(r, p, size) for r in rngs])


def _local_topk_scores_batch(n_tuples: np.ndarray, u: np.ndarray,
                             k: int) -> np.ndarray:
    """Batched ``local_topk_scores`` with pre-drawn uniforms u (T, n, k).

    Same per-element expressions as the scalar version — bit-for-bit."""
    T, n = n_tuples.shape
    out = np.empty((T, n, k))
    cur = np.ones((T, n))
    remaining = n_tuples.astype(np.float64)
    for j in range(k):
        cur = cur * u[:, :, j] ** (1.0 / np.maximum(remaining, 1.0))
        out[:, :, j] = cur
        remaining -= 1.0
    return out


def _local_topk_scores_batch_fast(n_tuples: np.ndarray, u: np.ndarray,
                                  k: int) -> np.ndarray:
    """Log-space form of the same order statistics: exp(Σ log(u_i)/rem_i).

    ~3× cheaper than the k pow passes; identical distribution but
    last-ulp different values — only used when entry-wise bit-parity
    with ``run_query`` is not required (shared-stream, E > 1)."""
    rem = np.maximum(n_tuples[..., None].astype(np.float64)
                     - np.arange(k), 1.0)
    out = np.log(u, out=u)                       # clobbers u (not reused)
    out /= rem
    np.cumsum(out, axis=2, out=out)
    return np.exp(out, out=out)


@dataclasses.dataclass
class EntryDraws:
    """Every per-entry RNG draw, in ``run_query_reference``'s exact order.

    Factored out of the numpy sweep so EVERY SimEngine backend consumes
    the same numpy-drawn arrays — backends may lower the sweeps to
    different hardware (see ``repro.engine.sim_jax``), but the
    stochastic inputs are bit-for-bit identical, which is what makes
    cross-backend parity a pure statement about the sweep math.

    ``rngs`` is left positioned exactly after the last pre-retrieval
    draw, so the exact retrieval path can continue each entry's stream
    where the scalar reference would.
    """
    exact: bool
    rngs: list                            # per-entry generators (or [g]*E)
    n_tuples: np.ndarray                  # (E, n) int
    scores: np.ndarray                    # (E, n, k) descending
    t_exec: np.ndarray                    # (E, n)
    up_term: np.ndarray                   # (E, n) lat + L_k / bw, v->parent
    dn_term: np.ndarray                   # (E, n) lat + Q / bw,  parent->v
    death: np.ndarray                     # (E, n); inf without churn
    item_sizes: Optional[np.ndarray]      # (E, n, k); None on fd fast path
    lam: Optional[np.ndarray]             # (E, n) st1/st1+2 random wait
    lat_o: Optional[np.ndarray]           # (E, n) cn/cn* originator links
    bw_o: Optional[np.ndarray]
    # latency_model="edge" only: (E, n) embedding latency origin -> v,
    # consumed by the retrieval epilogues in place of the iid lat draw
    origin_lat: Optional[np.ndarray] = None


def _precompute_draws(ent_origin: np.ndarray, seeds, n: int, p: SimParams,
                      algorithm: str, fw_strategy: str,
                      lifetime_mean_s: float, independent: bool,
                      par_lat: Optional[np.ndarray] = None,
                      origin_lat: Optional[np.ndarray] = None
                      ) -> EntryDraws:
    """All pre-retrieval draws for a flattened (E,) entry batch.

    The order is ``run_query_reference``'s: n_tuples, score uniforms,
    upward link, downward link, churn deaths, item sizes, then the
    per-algorithm extras (cn originator links / st1 wait lambdas).

    The churn draws live here too: ``death`` (exponential residual
    lifetimes, origin clamped immortal) is the ONE stochastic input the
    whole §4 machinery — peer removal, urgent forwarding, dead-parent
    rerouting — hinges on, so every backend consumes the same numpy
    deaths and churn parity reduces to sweep math.  Rerouting itself is
    deterministic in the paper's model (children go to the grandparent),
    so no further draws are needed.

    ``par_lat`` / ``origin_lat`` (both (E, n)) switch the link draws to
    the ``latency_model="edge"`` regime: latencies are the given
    embedding-derived values (tree-edge and origin-pair respectively)
    and only bandwidths are drawn — with ``_draw_bw``, the exact stream
    the scalar reference consumes in that mode.  Both backends receive
    the resulting ``up_term`` / ``dn_term`` / ``lat_o`` unchanged, so
    the latency model never breaks cross-backend bit parity.
    """
    E = len(seeds)
    k = p.k
    list_bytes = k * ENTRY_BYTES_PAPER
    if independent:
        rngs = [np.random.default_rng(s) for s in seeds]
        n_tuples = np.stack([r.integers(p.tuples_lo, p.tuples_hi + 1, n)
                             for r in rngs])
        u = np.stack([r.random((n, k)) for r in rngs])
    else:
        g = np.random.default_rng(int(seeds[0]))
        rngs = [g] * E
        n_tuples = g.integers(p.tuples_lo, p.tuples_hi + 1, (E, n))
        u = g.random((E, n, k))
    exact = independent or E == 1
    scores = (_local_topk_scores_batch(n_tuples, u, k) if exact
              else _local_topk_scores_batch_fast(n_tuples, u, k))
    t_exec = n_tuples * p.exec_s_per_tuple
    if par_lat is not None:
        if independent:
            bw_up = _draw_bw_batch(rngs, p, n)
            bw_dn = _draw_bw_batch(rngs, p, n)
        else:
            bw_up = _draw_bw(g, p, (E, n))
            bw_dn = _draw_bw(g, p, (E, n))
        lat_up = lat_dn = par_lat
    elif independent:
        lat_up, bw_up = _draw_link_batch(rngs, p, n)
        lat_dn, bw_dn = _draw_link_batch(rngs, p, n)
    else:
        lat_up, bw_up = _draw_link(g, p, (E, n))
        lat_dn, bw_dn = _draw_link(g, p, (E, n))
    if math.isinf(lifetime_mean_s):
        death = np.full((E, n), np.inf)
    else:
        if independent:
            death = np.stack([r.exponential(lifetime_mean_s, n)
                              for r in rngs])
        else:
            death = g.exponential(lifetime_mean_s, (E, n))
        death[np.arange(E), ent_origin] = np.inf
    # FD never reads the item-size values — only their stream position
    # matters, and only for entry-wise parity (independent / E == 1)
    item_sizes = None
    if algorithm != "fd" or exact:
        if independent:
            item_sizes = np.stack([np.maximum(
                r.normal(p.item_mean_B, p.item_std_B, (n, k)), 64.0)
                for r in rngs])
        else:
            item_sizes = np.maximum(
                g.normal(p.item_mean_B, p.item_std_B, (E, n, k)), 64.0)
    lam = lat_o = bw_o = None
    if algorithm in ("cn", "cn_star"):
        if origin_lat is not None:
            lat_o = origin_lat
            bw_o = (_draw_bw_batch(rngs, p, n) if independent
                    else _draw_bw(g, p, (E, n)))
        elif independent:
            lat_o, bw_o = _draw_link_batch(rngs, p, n)
        else:
            lat_o, bw_o = _draw_link(g, p, (E, n))
    elif fw_strategy != "basic":
        if independent:
            lam = np.stack([r.random(n) for r in rngs]) * p.lam_max_s
        else:
            lam = g.random((E, n)) * p.lam_max_s
    return EntryDraws(
        exact=exact, rngs=rngs, n_tuples=n_tuples, scores=scores,
        t_exec=t_exec, up_term=lat_up + list_bytes / bw_up,
        dn_term=lat_dn + QUERY_BYTES / bw_dn, death=death,
        item_sizes=item_sizes, lam=lam, lat_o=lat_o, bw_o=bw_o,
        origin_lat=origin_lat)


class _OriginStatic:
    """Trial-independent per-origin state (shared by all trials).

    ``edge_lat`` — the plan's CSR-aligned per-edge latency array
    (present when the topology carries coordinates): gathered here into
    ``par_lat`` (each node's tree-edge latency, the deterministic half
    of the ``latency_model="edge"`` link draws) and complemented by
    ``origin_lat`` (embedding latency origin -> v for the direct
    retrieval / CN originator links).
    """

    def __init__(self, top: Topology, indptr, indices, e_src, e_dst,
                 edge_keys, degrees, origin: int, ttl: int,
                 fw_strategy: str, bfs=None, edge_lat=None):
        n = top.n
        if bfs is not None:           # precomputed by the multi-origin BFS
            parent, depth, reached = bfs[:3]
            rank = bfs[3] if len(bfs) > 3 else None
            self.ttl = int(depth.max()) if ttl == 0 else ttl
        elif ttl == 0:
            # auto TTL = eccentricity: the full-depth BFS *is* the
            # TTL-limited BFS at that TTL, so reuse it
            parent, depth, reached, rank = bfs_tree_csr(
                indptr, indices, origin, n, return_rank=True)
            self.ttl = int(depth.max())
        else:
            self.ttl = ttl
            parent, depth, reached, rank = bfs_tree_csr(
                indptr, indices, origin, self.ttl, return_rank=True)
        self.parent, self.depth, self.reached = parent, depth, reached
        # within-level discovery ranks: the first-touch certificate the
        # live-overlay tree patch compares claims with (None only when a
        # caller passed a rank-less bfs tuple; such statics fall back to
        # the full BFS on every sync)
        self.rank = rank
        self.origin = origin
        self.idx = np.flatnonzero(reached)
        self.ttl_rem = np.maximum(self.ttl - depth, 0)
        dmax = int(depth.max())
        self.levels = [np.flatnonzero(depth == d) for d in range(dmax + 1)]
        # children CSR: grouped by parent, ascending within each parent —
        # the order run_query builds its per-node lists in
        childs = self.idx[parent[self.idx] >= 0]
        par = parent[childs]
        ordk = np.argsort(par, kind="stable")
        self.kid_sorted = childs[ordk]
        self.kid_ptr = np.searchsorted(par[ordk], np.arange(n + 1))
        self.fw_strategy = fw_strategy
        self.refresh_edges(top, e_src, e_dst, edge_keys, degrees, edge_lat)

    def refresh_edges(self, top: Topology, e_src, e_dst, edge_keys,
                      degrees, edge_lat) -> None:
        """(Re)derive everything that reads the GLOBAL edge arrays.

        The BFS tree (``parent`` / ``depth`` / ``reached`` / levels /
        child CSR) only sees edges on the tree, but the forward-phase
        masks, message counts, and latency gathers see every edge —
        ``NetworkPlan.sync`` calls this after an edge delta that left
        this origin's BFS tree unchanged, instead of rebuilding the
        whole static."""
        n = top.n
        parent, depth, reached = self.parent, self.depth, self.reached
        origin = self.origin
        self.n_edges_pq = int(((e_src < e_dst) & reached[e_src]
                               & reached[e_dst]).sum())
        self.avg_degree = float(np.mean(degrees[self.idx]))

        # ---- per-edge latency gathers (latency_model="edge") -----------
        if edge_lat is not None:
            self.par_lat = np.full(n, top.lat_base_s)
            ch = self.idx[parent[self.idx] >= 0]
            pos = np.searchsorted(edge_keys, ch * n + parent[ch])
            self.par_lat[ch] = edge_lat[pos]
            self.origin_lat = top.pair_latency(origin, np.arange(n))
        else:
            self.par_lat = self.origin_lat = None

        # ---- forward-phase static masks --------------------------------
        mask_u = reached & (self.ttl_rem > 0)
        self.m_basic = int(degrees[mask_u].sum() - mask_u.sum()
                           + int(mask_u[origin]))
        fw_strategy = self.fw_strategy
        if fw_strategy == "basic":
            return
        pu_e = parent[e_src]
        active = reached[e_src] & (self.ttl_rem[e_src] > 0) & (e_dst != pu_e)
        unreach = active & ~reached[e_dst]
        rest = active & reached[e_dst]
        if fw_strategy == "st1+2" and len(edge_keys):
            # Strategy 2 skip: v already reached by parent(u)'s send —
            # membership test (parent(u), v) ∈ E via the sorted key array
            m2 = rest & (pu_e >= 0)
            key = pu_e * n + e_dst
            pos = np.minimum(np.searchsorted(edge_keys, key[m2]),
                             len(edge_keys) - 1)
            member = np.zeros(len(e_src), bool)
            member[m2] = edge_keys[pos] == key[m2]
            rest = rest & ~member
        tree = rest & (parent[e_dst] == e_src)
        self.fw_static = int(unreach.sum() + tree.sum())
        els = np.flatnonzero(rest & ~tree)
        self.fw_els_src = e_src[els]
        self.fw_els_dst = e_dst[els]
        self.fw_cond = ((parent[self.fw_els_src] == self.fw_els_dst)
                        | (depth[self.fw_els_dst]
                           <= depth[self.fw_els_src]))

    def _classify_edges(self, pos, e_src, e_dst, edge_keys, base,
                        parent, depth, reached, ttl_rem):
        """refresh_edges' per-edge pipeline on a POSITION SUBSET.

        Returns (u, v, unreach, tree, els) booleans per position —
        exactly what the full pass would compute for those edges, so
        the delta patch below can subtract old and add new
        contributions without touching the rest."""
        u = e_src[pos].astype(np.int64)
        v = e_dst[pos].astype(np.int64)
        pu = parent[u]
        active = reached[u] & (ttl_rem[u] > 0) & (v != pu)
        unreach = active & ~reached[v]
        rest = active & reached[v]
        if self.fw_strategy == "st1+2" and len(edge_keys):
            m2 = rest & (pu >= 0)
            key = pu * base + v
            p_ = np.minimum(np.searchsorted(edge_keys, key[m2]),
                            len(edge_keys) - 1)
            member = np.zeros(len(u), bool)
            member[m2] = edge_keys[p_] == key[m2]
            rest = rest & ~member
        tree = rest & (parent[v] == u)
        return u, v, unreach, tree, rest & ~tree

    @classmethod
    def patched(cls, old: "_OriginStatic", top: Topology, indptr,
                indices, e_src, e_dst, edge_keys, degrees,
                requested_ttl: int, bfs, edge_lat, old_csr, removed,
                added) -> Optional["_OriginStatic"]:
        """Incremental rebuild for a SMALL tree delta — the live-overlay
        fast path behind ``NetworkPlan.sync``.

        ``bfs`` is the freshly recomputed (parent, depth, reached) on
        the patched CSR; ``old_csr`` the pre-mutation
        ``(n, indptr, indices, e_src, e_dst, edge_keys)``; ``removed``
        / ``added`` the net undirected edge delta from the overlay
        journal.  Wherever old and new BFS trees are bit-identical the
        old static's compiled structure is adopted wholesale; only
        levels, child-CSR rows, and per-edge classifications the delta
        can reach are re-derived — including the Strategy-2 membership
        coupling (an edge (p, w) appearing or vanishing re-classifies
        edges (u, w) of p's tree children).  Returns None for large or
        structural deltas (resolved TTL moved, origin departed, diff
        beyond budget): the caller falls back to a full rebuild.  The
        result is field-for-field equal to a from-scratch
        ``_OriginStatic`` — asserted by the overlay fuzz tests and the
        ``overlay_dynamics`` bench parity bit.
        """
        P, D, R = bfs[:3]
        K = bfs[3] if len(bfs) > 3 else None
        n = top.n
        old_n, old_indptr, old_indices, old_e_src, old_e_dst, old_keys \
            = old_csr
        resolved = int(D.max()) if requested_ttl == 0 else requested_ttl
        if old_n == n:
            op_, od_ = old.parent, old.depth
            or_, otr = old.reached, old.ttl_rem
        else:                     # peers joined: pad the old view
            pad = n - old_n
            op_ = np.concatenate([old.parent,
                                  np.full(pad, -1, old.parent.dtype)])
            od_ = np.concatenate([old.depth,
                                  np.full(pad, -1, old.depth.dtype)])
            or_ = np.concatenate([old.reached, np.zeros(pad, bool)])
            otr = np.maximum(old.ttl - od_, 0)
        diff = np.flatnonzero((op_ != P) | (od_ != D))
        # a moved resolved TTL shifts ttl_rem everywhere, but the edge
        # classification only reads it through ``ttl_rem[u] > 0`` — the
        # bit flips exactly for sources with depth in [min_ttl, max_ttl),
        # so re-deriving THEIR out-edges (old and new basis) absorbs an
        # eccentricity change without a full rebuild
        if resolved == old.ttl:
            tfl_old = tfl_new = np.zeros(0, np.int64)
        else:
            lo, hi = sorted((resolved, old.ttl))
            tfl_old = np.flatnonzero((od_ >= lo) & (od_ < hi))
            tfl_new = np.flatnonzero((D >= lo) & (D < hi))
        budget = 64 + n // 128
        if (len(diff) + len(tfl_old) + len(tfl_new) > budget
                or len(removed) + len(added) > budget):
            return None
        st = copy.copy(old)
        st.parent, st.depth, st.reached = P, D, R
        st.rank = K
        st.ttl = resolved
        st.idx = np.flatnonzero(R)
        st.ttl_rem = np.maximum(resolved - D, 0)

        # ---- levels: recompute only depths the diff touches ------------
        dmax = int(D.max())
        touched = ({int(x) for x in od_[diff]}
                   | {int(x) for x in D[diff]}) - {-1}
        old_dmax = len(old.levels) - 1
        st.levels = [old.levels[d]
                     if (d <= old_dmax and d not in touched)
                     else np.flatnonzero(D == d)
                     for d in range(dmax + 1)]

        # ---- children CSR: drop / re-insert only the diff nodes --------
        kid = old.kid_sorted
        gone = diff[(diff < old_n)]
        gone = gone[op_[gone] >= 0]
        if len(gone):
            kid = kid[~np.isin(kid, gone)]
        ins = diff[P[diff] >= 0]
        if len(ins):
            kk = P[kid] * np.int64(n) + kid
            ik = P[ins] * np.int64(n) + ins
            o_ = np.argsort(ik, kind="stable")
            kid = np.insert(kid, np.searchsorted(kk, ik[o_]), ins[o_])
        st.kid_sorted = kid
        kp = np.zeros(n + 1, old.kid_ptr.dtype)
        np.cumsum(np.bincount(P[kid], minlength=n), out=kp[1:])
        st.kid_ptr = kp

        # ---- affected directed-edge positions, old and new sides -------
        def out_in_pos(nodes, indptr, indices, keys, base):
            pos = [np.zeros(0, np.int64)]
            for x in nodes:
                lo, hi = int(indptr[x]), int(indptr[x + 1])
                pos.append(np.arange(lo, hi, dtype=np.int64))  # out-edges
                us = indices[lo:hi].astype(np.int64)           # in-edges
                pos.append(np.searchsorted(keys, us * base + x))
            return pos

        def pair_pos(pairs, keys, base, lim):
            out = [np.zeros(0, np.int64)]
            for a, b in pairs:
                if a >= lim or b >= lim:
                    continue
                k = np.array([a * base + b, b * base + a], np.int64)
                p_ = np.searchsorted(keys, k)
                ok = p_ < len(keys)
                p_, k = p_[ok], k[ok]
                out.append(p_[keys[p_] == k])
            return out

        # Strategy-2 coupling: delta edge (p, w) re-classifies (u, w)
        # for u in p's tree children (old AND new tree)
        coup = []
        if old.fw_strategy == "st1+2":
            for a, b in list(removed) + list(added):
                for p, w in ((a, b), (b, a)):
                    if p < old_n:
                        cs = old.kid_sorted[old.kid_ptr[p]:
                                            old.kid_ptr[p + 1]]
                        coup.extend((int(u), w) for u in cs)
                    cs = kid[kp[p]:kp[p + 1]]
                    coup.extend((int(u), w) for u in cs)
        diff_old = diff[diff < old_n]
        A_old = [*out_in_pos(diff_old, old_indptr, old_indices,
                             old_keys, old_n),
                 *out_in_pos(tfl_old[tfl_old < old_n], old_indptr,
                             old_indices, old_keys, old_n),
                 *pair_pos(list(removed) + coup, old_keys, old_n, old_n)]
        A_new = [*out_in_pos(diff, indptr, indices, edge_keys, n),
                 *out_in_pos(tfl_new, indptr, indices, edge_keys, n),
                 *pair_pos(list(added) + coup, edge_keys, n, n)]
        A_old = np.unique(np.concatenate(A_old))
        A_new = np.unique(np.concatenate(A_new))

        # ---- O(n)-cheap aggregates: recompute outright -----------------
        st.avg_degree = float(np.mean(degrees[st.idx]))
        mask_u = R & (st.ttl_rem > 0)
        st.m_basic = int(degrees[mask_u].sum() - mask_u.sum()
                         + int(mask_u[old.origin]))

        # ---- per-edge latency gathers ----------------------------------
        if edge_lat is not None:
            pl = (old.par_lat.copy() if old_n == n else np.concatenate(
                [old.par_lat, np.full(n - old_n, top.lat_base_s)]))
            pl[diff] = top.lat_base_s
            ch = diff[P[diff] >= 0]
            if len(ch):
                pos = np.searchsorted(edge_keys,
                                      ch * np.int64(n) + P[ch])
                pl[ch] = edge_lat[pos]
            st.par_lat = pl
            st.origin_lat = (old.origin_lat if old_n == n
                             else np.concatenate([
                                 old.origin_lat,
                                 top.pair_latency(old.origin,
                                                  np.arange(old_n, n))]))

        # ---- classify the affected edges, old vs new -------------------
        uo, vo, uno, tro, elo = old._classify_edges(
            A_old, old_e_src, old_e_dst, old_keys, old_n,
            op_, od_, or_, otr)
        un, vn, unn, trn, eln = st._classify_edges(
            A_new, e_src, e_dst, edge_keys, n, P, D, R, st.ttl_rem)
        mo, mn = uo < vo, un < vn
        st.n_edges_pq = (old.n_edges_pq
                         - int((or_[uo[mo]] & or_[vo[mo]]).sum())
                         + int((R[un[mn]] & R[vn[mn]]).sum()))
        if old.fw_strategy == "basic":
            return st
        st.fw_static = (old.fw_static - int(uno.sum() + tro.sum())
                        + int(unn.sum() + trn.sum()))
        # els content patch, (src, dst)-ascending order preserved:
        # every affected pair is dropped, then the still-els ones are
        # re-inserted at their sorted position with a fresh cond
        n64 = np.int64(n)
        ek = old.fw_els_src.astype(np.int64) * n64 + old.fw_els_dst
        keep = ~np.isin(ek, uo * n64 + vo)
        src = old.fw_els_src[keep]
        dst = old.fw_els_dst[keep]
        cond = old.fw_cond[keep]
        iu, iv = un[eln], vn[eln]
        if len(iu):
            ik = iu * n64 + iv
            o_ = np.argsort(ik, kind="stable")
            iu, iv, ik = iu[o_], iv[o_], ik[o_]
            p_ = np.searchsorted(ek[keep], ik)
            src = np.insert(src, p_, iu.astype(src.dtype))
            dst = np.insert(dst, p_, iv.astype(dst.dtype))
            cond = np.insert(cond, p_, (P[iu] == iv) | (D[iv] <= D[iu]))
        st.fw_els_src, st.fw_els_dst, st.fw_cond = src, dst, cond
        return st


def _entry_latencies(sts, ent_st: np.ndarray, p: SimParams):
    """(par_lat, origin_lat) as (E, n) entry-expanded arrays, or (None,
    None) in the default iid model (backend-shared helper)."""
    if p.latency_model != "edge":
        return None, None
    if sts[0].par_lat is None:
        raise ValueError(
            "latency_model='edge' needs node coordinates; this "
            "topology has none (use a coordinate-carrying generator "
            "from repro.p2psim.topologies)")
    return (np.stack([st.par_lat for st in sts])[ent_st],
            np.stack([st.origin_lat for st in sts])[ent_st])


def _topk_remerge(mvals_row, mown_row, extra_v, extra_o, k):
    """Exact: top-k(top-k(A) ∪ B) == top-k(A ∪ B) for distinct values."""
    allm = np.concatenate([mvals_row] + extra_v)
    allo = np.concatenate([mown_row] + extra_o)
    sel = np.argsort(allm)[::-1][:k]
    return allm[sel], allo[sel]


def _run_entries(sts, ent_st: np.ndarray, ent_origin: np.ndarray,
                 seeds, n: int, p: SimParams, algorithm: str,
                 dynamic: bool, lifetime_mean_s: float,
                 independent: bool, replicas=None) -> dict:
    """Every (query, trial) entry at once — the flattened batch axis E.

    ``sts``: unique ``_OriginStatic`` list; ``ent_st[e]`` indexes into it.
    All sweeps run over (E × peers/edges) arrays and the merge walks tree
    levels ONCE globally, bucketing nodes by child count so every bucket
    is a dense (rows × children × k) tensor op.  Returns (E,) metric
    arrays.

    ``independent=True``: entry e draws from its own Generator seeded
    ``seeds[e]`` in run_query's exact call order — bit-for-bit entry-wise
    parity with ``run_query``.  ``independent=False``: one shared stream
    seeded ``seeds[0]`` issues batch-shaped draws; for E == 1 that stream
    is run_query's exactly (array shape (1, n) consumes the generator
    identically to (n,)), so a batch of one is still bit-for-bit equal;
    for E > 1 the entries are i.i.d. but not entry-wise reproducible, and
    draws whose *values* are unused (FD never reads item sizes) are
    skipped for speed.
    """
    E = len(seeds)
    S = len(sts)
    k = p.k
    list_bytes = k * ENTRY_BYTES_PAPER
    ent_of_st = [np.flatnonzero(ent_st == s) for s in range(S)]

    # ---- RNG draws, run_query's exact order (shared by all backends) ----
    par_lat, origin_lat = _entry_latencies(sts, ent_st, p)
    draws = _precompute_draws(ent_origin, seeds, n, p, algorithm,
                              sts[0].fw_strategy, lifetime_mean_s,
                              independent, par_lat, origin_lat)
    scores, t_exec, death = draws.scores, draws.t_exec, draws.death

    # ---- level row sets: (entry, node, parent, kid-slice) per depth -----
    kid_concat = (np.concatenate([st.kid_sorted for st in sts])
                  if any(len(st.kid_sorted) for st in sts)
                  else np.zeros(0, np.int64))
    off = 0
    ksg = []
    for st in sts:
        ksg.append(st.kid_ptr + off)
        off += len(st.kid_sorted)
    dmax = max(len(st.levels) for st in sts) - 1
    # per st: entry-expanded arrays over all reached nodes, NODE-MAJOR and
    # ordered by depth — each level is then a contiguous slice, so the
    # per-level row set is one concatenate per array instead of per-st
    # repeat/tile calls inside the level loop
    st_rows = []
    for s, st in enumerate(sts):
        es = ent_of_st[s]
        nE = len(es)
        vs_all = np.concatenate(st.levels)
        bounds = np.cumsum([0] + [len(lv) for lv in st.levels]) * nE
        vv_st = np.repeat(vs_all, nE)
        ee_st = np.tile(es, len(vs_all))
        pp_st = np.repeat(st.parent[vs_all], nE)
        ks_st = np.repeat(ksg[s][vs_all], nE)
        cnt_st = np.repeat(st.kid_ptr[vs_all + 1] - st.kid_ptr[vs_all], nE)
        st_rows.append((bounds, ee_st, vv_st, pp_st, ks_st, cnt_st))
    rows = []                                # rows[d] = (ee, vv, pp, ks, cnt)
    for d in range(dmax + 1):
        parts = [[], [], [], [], []]
        for s, st in enumerate(sts):
            if d >= len(st.levels):
                continue
            bounds = st_rows[s][0]
            lo, hi = bounds[d], bounds[d + 1]
            if lo == hi:
                continue
            for i in range(5):
                parts[i].append(st_rows[s][i + 1][lo:hi])
        rows.append(tuple(
            np.concatenate(a) if a else np.zeros(0, np.int64)
            for a in parts))

    # ---- query arrival down the tree ------------------------------------
    t_q = np.full((E, n), np.inf)
    t_q[np.arange(E), ent_origin] = 0.0
    dn_term = draws.dn_term                      # same float grouping as
    for d in range(1, dmax + 1):                 # _link_time per element
        ee, vv, pp, _, _ = rows[d]
        if len(ee) == 0:
            continue
        t_q[ee, vv] = t_q[ee, pp] + dn_term[ee, vv]
    t_ex_done = t_q + t_exec

    out = _empty_out(E, k)
    m_basic_arr = np.array([st.m_basic for st in sts], np.int64)

    # ---- CN / CN* baselines --------------------------------------------
    if algorithm in ("cn", "cn_star"):
        out["m_fw"][:] = m_basic_arr[ent_st]
        _cn_entries(out, draws, sts, ent_st, ent_origin, t_ex_done, p,
                    algorithm)
        return out

    # ---- FD: forward phase ----------------------------------------------
    if sts[0].fw_strategy == "basic":
        out["m_fw"][:] = m_basic_arr[ent_st]
    else:
        lam = draws.lam
        tqf = np.stack([np.where(st.depth >= 0, st.depth * p.t_qsnd_s,
                                 np.inf) for st in sts])
        send_at = tqf[ent_st] + lam                          # (E, n)
        for s, st in enumerate(sts):
            es = ent_of_st[s]
            if len(st.fw_els_src) == 0:
                out["m_fw"][es] = st.fw_static
                continue
            slt = (send_at[np.ix_(es, st.fw_els_dst)]
                   < send_at[np.ix_(es, st.fw_els_src)])
            skip = (slt & st.fw_cond[None, :]).sum(axis=1)
            out["m_fw"][es] = st.fw_static + len(st.fw_els_src) - skip

    # ---- FD: merge-and-backward, deepest level first --------------------
    wt = np.stack([wait_time(st.ttl_rem, p) for st in sts])  # (S, n)
    deadline = t_q + wt[ent_st]
    send_t = np.zeros((E, n))
    valid = np.zeros((E, n), bool)
    # only reached nodes are ever read, and each is written at its level
    # before any reader (parent / origin gather) — no init needed
    mvals = np.empty((E, n, k))
    mown = np.empty((E, n, k), np.int32)
    urgent: list = [[] for _ in range(E)]      # per entry: (eta, peer)
    m_bw = out["m_bw"]
    b_bw = out["b_bw"]
    up_term = draws.up_term                    # arrival link time per node
    no_churn = math.isinf(lifetime_mean_s)
    if no_churn:
        # every reached non-origin peer is alive and sends exactly once;
        # urgent hops are added as they are discovered below
        n_reached_arr = np.array([len(st.idx) for st in sts], np.int64)
        m_bw += n_reached_arr[ent_st] - 1
        b_bw += (n_reached_arr[ent_st] - 1) * list_bytes

    for d in range(dmax, -1, -1):
        ee, vv, _, ks_row, cnt_row = rows[d]
        if len(ee) == 0:
            continue
        reroute = []
        # bucket rows by child count: each bucket is a dense
        # (rows × children) block — no padding waste, no slot loop
        ucnt, inv = np.unique(cnt_row, return_inverse=True)
        for bi, c in enumerate(ucnt):
            sel = np.flatnonzero(inv == bi)
            eeb, vvb = ee[sel], vv[sel]
            own_b = t_ex_done[eeb, vvb]
            c = int(c)
            if c:
                C = kid_concat[ks_row[sel][:, None]
                               + np.arange(c)[None, :]]     # (R, c)
                eb = eeb[:, None]
                a = send_t[eb, C] + up_term[eb, C]
                all_in = a.max(axis=1)
            else:
                all_in = np.zeros(len(sel))
            s_b = np.minimum(np.maximum(own_b, all_in),
                             np.maximum(deadline[eeb, vvb], own_b))
            if no_churn:              # everyone alive: straight commits,
                alive_b = None        # no masks, no valid[] bookkeeping
                send_t[eeb, vvb] = s_b
            else:
                alive_b = death[eeb, vvb] >= s_b
                send_t[eeb, vvb] = np.where(alive_b, s_b, np.inf)
                valid[eeb, vvb] = alive_b

            if c:
                R = len(sel)
                if no_churn:
                    ont = a <= s_b[:, None]
                    all_ontime = bool(ont.all())
                else:
                    kid_v = valid[eb, C]
                    ont = kid_v & (a <= s_b[:, None]) & alive_b[:, None]
                    all_ontime = False
                contrib_v = np.empty((R, c + 1, k))
                contrib_v[:, 0, :] = scores[eeb, vvb]
                contrib_v[:, 1:, :] = mvals[eb, C]
                if not all_ontime:
                    contrib_v[:, 1:, :][~ont] = -np.inf
                contrib_o = np.empty((R, c + 1, k), np.int32)
                contrib_o[:, 0, :] = vvb[:, None]
                contrib_o[:, 1:, :] = mown[eb, C]
                fv = contrib_v.reshape(R, -1)
                fo = contrib_o.reshape(R, -1)
                if c <= 3:            # small width: one argsort beats
                    selk = np.argsort(fv, axis=1)[:, :-(k + 1):-1]
                else:                 # partition+sort
                    part = np.argpartition(fv, -k, axis=1)[:, -k:]
                    pvv = np.take_along_axis(fv, part, axis=1)
                    selk = np.take_along_axis(
                        part, np.argsort(pvv, axis=1)[:, ::-1], axis=1)
                newv = np.take_along_axis(fv, selk, axis=1)
                newo = np.take_along_axis(fo, selk, axis=1)
            else:
                all_ontime = True
                newv = scores[eeb, vvb]
                newo = np.repeat(vvb[:, None], k, axis=1).astype(np.int32)
            if no_churn:
                mvals[eeb, vvb] = newv
                mown[eeb, vvb] = newo
            else:
                mvals[eeb, vvb] = np.where(alive_b[:, None], newv, -np.inf)
                mown[eeb, vvb] = np.where(alive_b[:, None], newo, -1)
                sends_b = alive_b & (vvb != ent_origin[eeb])
                cnt_send = np.bincount(eeb[sends_b], minlength=E)
                m_bw += cnt_send
                b_bw += cnt_send * list_bytes

            if dynamic and c and not all_ontime:
                late = ~ont if no_churn else (
                    kid_v & (a > s_b[:, None]) & alive_b[:, None])
                ri, ci = np.nonzero(late)
                if len(ri):
                    etas = a[ri, ci] + d * (p.latency_mean_s
                                            + list_bytes / p.bw_mean_Bps)
                    for r_, c_, eta in zip(ri, C[ri, ci], etas):
                        urgent[int(eeb[r_])].append((eta, int(c_)))
                    late_cnt = np.bincount(eeb[ri], minlength=E)
                    m_bw += late_cnt * d
                    b_bw += late_cnt * (d * list_bytes)
                if not no_churn:
                    deadk = (~kid_v) & alive_b[:, None]
                    ri, ci = np.nonzero(deadk)
                    for r_, c_ in zip(ri, C[ri, ci]):
                        reroute.append((int(eeb[r_]), int(vvb[r_]),
                                        int(c_)))

        # dead-parent reroute (§4.2): grandchildren lists join v directly
        for e_, v_, c_ in reroute:
            s_ = ent_st[e_]
            ev, eo = [], []
            for cc in kid_concat[ksg[s_][c_]:ksg[s_][c_ + 1]]:
                if valid[e_, cc] and send_t[e_, cc] < np.inf:
                    ev.append(mvals[e_, cc])
                    eo.append(mown[e_, cc])
                    m_bw[e_] += 1
                    b_bw[e_] += list_bytes
            if ev:
                mvals[e_, v_], mown[e_, v_] = _topk_remerge(
                    mvals[e_, v_], mown[e_, v_], ev, eo, k)

    top_true_all = _true_topk_by_origin(scores, sts, ent_of_st, k)
    t_merge_done = send_t[np.arange(E), ent_origin] + p.merge_s
    _accept_urgent_origin(urgent, ent_origin, t_merge_done, mvals, mown,
                          None if no_churn else valid, k)
    ar = np.arange(E)
    out["values"] = mvals[ar, ent_origin]
    out["owners"] = mown[ar, ent_origin].astype(np.int64)
    if draws.exact:
        _retrieval_exact(out, draws, ent_origin, t_merge_done, mvals,
                         mown, top_true_all, p, replicas)
    else:
        _retrieval_shared(out, draws, ent_origin, t_merge_done, mvals,
                          mown, top_true_all, p, replicas)
    return out


def _empty_out(E: int, k: Optional[int] = None) -> dict:
    out = {f: np.zeros(E, np.int64)
           for f in ("m_fw", "m_bw", "m_rt", "b_bw", "b_rt")}
    out["response_time_s"] = np.zeros(E)
    out["accuracy"] = np.zeros(E)
    if k is not None:
        # the origin's merged k-list (descending values + owning peers)
        # — what the precision tolerance contract compares across runs
        out["values"] = np.full((E, k), -np.inf)
        out["owners"] = np.full((E, k), -1, np.int64)
    return out


def _cn_entries(out: dict, draws: EntryDraws, sts, ent_st: np.ndarray,
                ent_origin: np.ndarray, t_ex_done: np.ndarray,
                p: SimParams, algorithm: str) -> None:
    """CN / CN* baselines given arrival times (backend-shared)."""
    E = len(ent_st)
    k = p.k
    n = t_ex_done.shape[1]
    list_bytes = k * ENTRY_BYTES_PAPER
    scores, death = draws.scores, draws.death
    item_sizes, lat_o = draws.item_sizes, draws.lat_o
    for e in range(E):
        idx = sts[ent_st[e]].idx
        origin = int(ent_origin[e])
        per_peer = (item_sizes[e][:, :k].sum(1) if algorithm == "cn"
                    else np.full(n, float(list_bytes)))
        alive = death[e] > t_ex_done[e]
        senders = idx[alive[idx]]
        senders = senders[senders != origin]
        out["m_bw"][e] = len(senders)
        out["b_bw"][e] = int(per_peer[senders].sum())
        own_bw = max(p.bw_mean_Bps, 1.0)
        t_arrive = t_ex_done[e][senders] + lat_o[e][senders]
        t_resp = (np.max(t_arrive) if len(senders) else 0.0) \
            + per_peer[senders].sum() / own_bw
        if algorithm == "cn_star":
            true_full = np.full((n, k), -np.inf)
            true_full[idx] = scores[e][idx]
            flat = true_full.reshape(-1)
            top_idx = np.argpartition(flat, -k)[-k:]
            owners = np.unique(top_idx // k)
            out["m_rt"][e] = 2 * len(owners)
            out["b_rt"][e] = int(
                out["m_rt"][e] / 2 * p.request_B
                + item_sizes[e].reshape(-1)[top_idx].sum())
            t_resp += 2 * p.latency_mean_s + out["b_rt"][e] / own_bw
        out["response_time_s"][e] = float(t_resp)
        delivered = np.zeros(n, bool)
        delivered[senders] = True
        delivered[origin] = True
        out["accuracy"][e] = _accuracy(scores[e], idx, delivered, k)
        if "values" in out:
            # the origin's collected k-list: top-k over every delivered
            # peer's items (the origin always delivers to itself)
            didx = idx[delivered[idx]]
            sc = scores[e][didx].reshape(-1)
            top = np.argpartition(sc, -k)[-k:]
            top = top[np.argsort(sc[top])[::-1]]
            out["values"][e] = sc[top]
            out["owners"][e] = didx[top // k]


def _true_topk_by_origin(scores: np.ndarray, sts, ent_of_st,
                         k: int) -> np.ndarray:
    """(E, k) true top-k of each entry's reach set, grouped by origin."""
    E = scores.shape[0]
    top_true_all = np.empty((E, k))
    for s, st in enumerate(sts):
        es = ent_of_st[s]
        block = scores[np.ix_(es, st.idx)].reshape(len(es), -1)
        part = np.partition(block, -k, axis=1)[:, -k:]
        top_true_all[es] = np.sort(part, axis=1)[:, ::-1]
    return top_true_all


def _reroute_counts(st, valid_rows: np.ndarray) -> np.ndarray:
    """Per-entry count of §4.2 dead-parent reroutes (backend-shared).

    A reroute message is sent per grandchild ``cc`` whose parent died
    before its send time while both ``cc`` and the grandparent survive
    — exactly the lists the numpy sweep re-merges and the jax sweep's
    masked reroute fold accepts.  ``valid_rows``: (entries, n) liveness
    (True = alive at its send time) for this origin's entries.
    """
    ch = st.kid_sorted
    pr = st.parent[ch]
    has_gp = st.parent[pr] >= 0
    cc, pp = ch[has_gp], pr[has_gp]
    gp = st.parent[pp]
    return (valid_rows[:, cc] & ~valid_rows[:, pp]
            & valid_rows[:, gp]).sum(axis=1)


def _accept_urgent_origin(urgent, ent_origin: np.ndarray,
                          t_merge_done: np.ndarray, mvals: np.ndarray,
                          mown: np.ndarray, valid: Optional[np.ndarray],
                          k: int) -> None:
    """Fold urgent lists arriving before retrieval into the origin's
    merge (``valid`` is None when churn is off — everyone is alive)."""
    for e in range(len(ent_origin)):
        if not urgent[e]:
            continue
        origin = int(ent_origin[e])
        ok = [c for (eta, c) in urgent[e]
              if eta <= t_merge_done[e]
              and (valid is None or valid[e, c])]
        if ok and (valid is None or valid[e, origin]):
            mvals[e, origin], mown[e, origin] = _topk_remerge(
                mvals[e, origin], mown[e, origin],
                [mvals[e, c] for c in ok], [mown[e, c] for c in ok], k)


def _retrieval_exact(out: dict, draws: EntryDraws, ent_origin: np.ndarray,
                     t_merge_done: np.ndarray, mvals: np.ndarray,
                     mown: np.ndarray, top_true_all: np.ndarray,
                     p: SimParams, replicas=None) -> None:
    """run_query's per-entry retrieval, verbatim (bit-for-bit parity).

    ``replicas`` — the plan's (n, r) placement table (None = replication
    off): a dead owner's items are served by its first alive replica,
    exactly the scalar reference's fallback."""
    k = p.k
    death, rngs = draws.death, draws.rngs
    for e in range(len(ent_origin)):
        origin = int(ent_origin[e])
        final_owners = np.unique(mown[e, origin])
        served = _serving_peers(final_owners, replicas, death[e],
                                t_merge_done[e])
        srv = served >= 0
        out["m_rt"][e] = 2 * int(srv.sum())
        if draws.origin_lat is None:
            lat_o, bw_o = _draw_link(rngs[e], p, len(final_owners))
        else:
            lat_o = draws.origin_lat[
                e, np.where(srv, served, final_owners)]
            bw_o = _draw_bw(rngs[e], p, len(final_owners))
        per_owner_counts = np.array(
            [(mown[e, origin] == o).sum() for o in final_owners])
        fetch_bytes = per_owner_counts * p.item_mean_B
        out["b_rt"][e] = int(srv.sum() * p.request_B
                             + fetch_bytes[srv].sum())
        t_fetch = (2 * lat_o + (p.request_B + fetch_bytes) / bw_o)
        t_fetch = t_fetch[srv]
        out["response_time_s"][e] = float(
            t_merge_done[e] + (t_fetch.max() if len(t_fetch) else 0.0))

        got = mvals[e, origin]              # sorted descending
        inter = np.intersect1d(top_true_all[e], got).size
        lost_owned = np.isin(mown[e, origin], final_owners[~srv])
        inter = max(0, inter - int(np.isin(
            mvals[e, origin][lost_owned], top_true_all[e]).sum()))
        out["accuracy"][e] = inter / k


def _retrieval_shared(out: dict, draws: EntryDraws,
                      ent_origin: np.ndarray, t_merge_done: np.ndarray,
                      mvals: np.ndarray, mown: np.ndarray,
                      top_true_all: np.ndarray, p: SimParams,
                      replicas=None) -> None:
    """Shared-stream fast path: the same retrieval model, vectorized over
    all entries at once (draw assignment to owners differs but is
    i.i.d. — distributionally identical to the scalar path).

    ``replicas`` — (n, r) placement table (None = replication off): a
    dead owner's items are served by its first alive replica.  With
    ``replicas=None`` every expression below reduces bit-for-bit to the
    replication-free code (``served == mo`` wherever it is read)."""
    E = len(ent_origin)
    k = p.k
    death = draws.death
    ar = np.arange(E)
    mo = mown[ar, ent_origin]                                # (E, k)
    gv = mvals[ar, ent_origin]                               # (E, k)
    dth = death[ar[:, None], mo]                             # (E, k)
    alive_elem = dth > t_merge_done[:, None]
    if replicas is None or replicas.shape[1] == 0:
        served = np.where(alive_elem, mo, -1)
    else:
        rep = replicas[np.maximum(mo, 0)]                    # (E, k, r)
        rep_ok = (rep >= 0) & (death[ar[:, None, None],
                                     np.maximum(rep, 0)]
                               > t_merge_done[:, None, None])
        first = np.take_along_axis(
            rep, rep_ok.argmax(axis=2)[..., None], axis=2)[..., 0]
        served = np.where(alive_elem, mo,
                          np.where(rep_ok.any(axis=2) & (mo >= 0),
                                   first, -1))
    srv_elem = served >= 0
    eqm = mo[:, :, None] == mo[:, None, :]                   # (E, k, k)
    count_elem = eqm.sum(axis=2)                 # owner multiplicity
    firstocc = ~(eqm & np.tri(k, k, -1, dtype=bool)[None]).any(axis=2)
    srv_owner_cnt = (firstocc & srv_elem).sum(axis=1)
    out["m_rt"][:] = 2 * srv_owner_cnt
    # Σ_over-served-owners count_o · item_mean == #elements with a
    # serving peer · item_mean (exact: every term is an integer multiple)
    fetch_total = srv_elem.sum(axis=1) * p.item_mean_B
    out["b_rt"][:] = (srv_owner_cnt * p.request_B
                      + fetch_total).astype(np.int64)
    if draws.origin_lat is None:
        lat_o, bw_o = _draw_link(draws.rngs[0], p, (E, k))  # per owner slot
    else:            # edge model: serving-peer latency deterministic
        lat_o = draws.origin_lat[ar[:, None],
                                 np.where(srv_elem, served, mo)]
        bw_o = _draw_bw(draws.rngs[0], p, (E, k))
    t_f = 2 * lat_o + (p.request_B + count_elem * p.item_mean_B) / bw_o
    t_max = np.where(firstocc & srv_elem, t_f, -np.inf).max(axis=1)
    out["response_time_s"][:] = t_merge_done + np.where(
        np.isfinite(t_max), t_max, 0.0)

    match = (gv[:, :, None] == top_true_all[:, None, :]).any(axis=2)
    inter = match.sum(axis=1)
    corr = (match & ~srv_elem).sum(axis=1)
    out["accuracy"][:] = np.maximum(0, inter - corr) / k


def run_queries(top: Topology, origins,
                params: Optional[SimParams] = None,
                n_trials: int = 1, *, algorithm: str = "fd",
                strategy: str = "st1+2", dynamic: bool = True,
                lifetime_mean_s: float = float("inf"),
                seeds=None, independent_streams: bool = False
                ) -> BatchMetrics:
    """Batched multi-query simulation — thin shim over ``repro.engine``.

    Evaluates (len(origins) × n_trials) queries in one call; see
    ``repro.engine.SimEngine`` (the entrypoint, which additionally
    caches the compiled ``NetworkPlan`` across calls) for the execution
    model, and ``QuerySpec`` for the RNG modes:

      * default (shared stream) — one generator seeded ``params.seed``
        issues batch-shaped draws; a batch of ONE reproduces
        ``run_query`` bit-for-bit, larger batches are i.i.d.;
      * ``independent_streams=True`` (implied by passing ``seeds``) —
        entry (q, t) reproduces ``run_query`` with seed
        ``params.seed + q * n_trials + t`` (or ``seeds[q, t]``)
        bit-for-bit, entry by entry.

    .. deprecated:: use ``repro.engine.SimEngine`` with a ``QuerySpec``
       (``QuerySpec(origins=origins, n_trials=n_trials,
       rng="independent")``) — see the README migration table.
    """
    _legacy_gate(
        "run_queries is deprecated; use repro.engine.SimEngine with a "
        "QuerySpec(origins=..., n_trials=..., rng=...) — see the README "
        "migration table")
    from repro.engine import QuerySpec, SimEngine, policy_from_legacy
    pol = policy_from_legacy(algorithm, strategy, dynamic, lifetime_mean_s)
    spec = QuerySpec(
        origins=tuple(int(o) for o in np.atleast_1d(np.asarray(origins))),
        n_trials=n_trials, seeds=seeds,
        rng="independent" if independent_streams else "shared")
    return SimEngine(top, params).run(spec, pol).metrics


# --------------------------------------------------------------------------
# statistics heuristic (paper §3.3 + Fig 7)
# --------------------------------------------------------------------------

def run_statistics_heuristic(top: Topology, origin: int,
                             params: SimParams, z: float):
    """Two-round statistics heuristic — thin shim over the engine's
    ``"fd-stats"`` policy (see ``SimEngine._run_stats``): round 1 full
    FD gathers per-child best-rank stats; round 2 forwards Q only to
    children whose best past score ranked above z*k in the parent's
    merged list.  Returns (metrics_full, metrics_pruned,
    comm_reduction, accuracy).

    .. deprecated:: use ``repro.engine.SimEngine`` with the
       ``"fd-stats"`` policy (``get_policy("fd-stats").variant(z=z)``;
       rounds land in ``TopKResult.extras``) — see the README migration
       table.
    """
    _legacy_gate(
        "run_statistics_heuristic is deprecated; use repro.engine."
        "SimEngine with get_policy('fd-stats').variant(z=z) — rounds "
        "land in TopKResult.extras; see the README migration table")
    from repro.engine import QuerySpec, SimEngine, get_policy
    res = SimEngine(top, params).run(
        QuerySpec(origins=(int(origin),)),
        get_policy("fd-stats").variant(z=z))
    ex = res.extras
    return (ex["metrics_full"], ex["metrics_pruned"],
            ex["comm_reduction"], ex["accuracy"])
