"""Vectorized simulator of FD over an unstructured overlay (paper §3–§5).

Faithful to the paper's four phases with the Appendix-A wait-time model:

  * query forward — TTL flood; FD-Basic / Strategy 1 (randomized λ, each
    edge once w.h.p.) / Strategy 1+2 (piggybacked neighbor lists);
  * local execution — per-peer top-k of n_i ∈ [1000, 20000] uniform
    scores, sampled exactly via order statistics (no tuple
    materialization);
  * merge-and-backward — bottom-up k-list merge along the implicit
    spanning tree; a peer sends at its wait deadline or when all
    children reported, whichever is first; late lists are DROPPED by
    FD-Basic and bubbled as *urgent* lists by FD-Dynamic (§4.1);
  * data retrieval — direct fetch from the ≤ k winning owners.

Baselines (§5.1): CN (peers ship k data items to the originator),
CN* (peers ship k-lists to the originator); both compete for the
originator's bandwidth — the paper's central-node bottleneck.

Churn (§4/§5.4): exponential residual lifetimes; dead parents lose
subtrees in FD-Basic, FD-Dynamic reroutes via non-child neighbors or
directly to the originator.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.p2psim.graph import Topology, bfs_tree
from repro.p2psim.metrics import ENTRY_BYTES_PAPER, QUERY_BYTES, QueryMetrics


@dataclasses.dataclass
class SimParams:
    """Table 1 of the paper."""
    k: int = 20
    ttl: int = 0                    # 0 -> auto (reach everyone)
    latency_mean_s: float = 0.200   # N(200 ms, var 100 ms^2)
    latency_var: float = 0.100 ** 2
    bw_mean_Bps: float = 56_000.0 / 8.0      # 56 kbps
    bw_var: float = (32_000.0 / 8.0) ** 2
    tuples_lo: int = 1000
    tuples_hi: int = 20000
    item_mean_B: float = 1024.0     # result data item ~ N(1 KB, ...)
    item_std_B: float = 256.0
    exec_s_per_tuple: float = 2e-5  # T_exec(Q) ~ 0.02..0.4 s
    merge_s: float = 0.002          # T_Merge(k)
    lam_max_s: float = 0.05         # Strategy-1 random wait λ
    request_B: int = 50
    # Appendix-A wait-time cost parameters (MAX estimates)
    t_qsnd_s: float = 0.5
    t_exec_max_s: float = 0.5
    t_slsnd_s: float = 0.5
    seed: int = 0


# --------------------------------------------------------------------------
# local query execution: exact top-k order statistics of n uniforms
# --------------------------------------------------------------------------

def local_topk_scores(n_tuples: np.ndarray, k: int,
                      rng: np.random.Generator) -> np.ndarray:
    """(P, k) descending top-k of n_i U[0,1] scores, sampled exactly:
    top-1 = U^(1/n); successive gaps via the Rényi representation."""
    p = len(n_tuples)
    u = rng.random((p, k))
    out = np.empty((p, k))
    cur = np.ones(p)
    remaining = n_tuples.astype(np.float64)
    for j in range(k):
        cur = cur * u[:, j] ** (1.0 / np.maximum(remaining, 1.0))
        out[:, j] = cur
        remaining -= 1.0
    return out


def wait_time(ttl_rem: np.ndarray, p: SimParams) -> np.ndarray:
    """Appendix A formula (2)."""
    t = ttl_rem.astype(np.float64)
    return (t * p.t_qsnd_s + p.t_exec_max_s + t * p.t_slsnd_s
            + np.maximum(t - 1.0, 0.0) * p.merge_s)


def _link_time(nbytes: float, lat: np.ndarray, bw: np.ndarray) -> np.ndarray:
    return lat + nbytes / bw


def _draw_link(rng, p: SimParams, size):
    lat = np.maximum(rng.normal(p.latency_mean_s,
                                math.sqrt(p.latency_var), size), 1e-3)
    bw = np.maximum(rng.normal(p.bw_mean_Bps, math.sqrt(p.bw_var), size),
                    1_000.0)
    return lat, bw


# --------------------------------------------------------------------------
# forward-phase message counting
# --------------------------------------------------------------------------

def forward_messages(top: Topology, origin: int, parent, depth, reached,
                     strategy: str, p: SimParams,
                     rng: np.random.Generator,
                     child_allowed: Optional[np.ndarray] = None) -> int:
    """Count forward messages for basic / st1 / st1+2.

    ``child_allowed``: bool (n,) — statistics-heuristic pruning: peers a
    parent refuses to forward to (their subtree never receives Q) must be
    handled by the caller re-running bfs on the pruned graph; here it only
    restricts the counting.
    """
    n = top.n
    ttl = p.ttl
    ttl_rem = ttl - depth
    if strategy == "basic":
        m = 0
        for u in range(n):
            if not reached[u] or ttl_rem[u] <= 0:
                continue
            deg = len(top.neighbors[u])
            m += deg if u == origin else deg - 1
        return m
    # strategy 1 / 1+2: randomized λ per peer; send only to neighbors not
    # yet heard from
    lam = rng.random(n) * p.lam_max_s
    t_q = np.where(depth >= 0, depth * p.t_qsnd_s, np.inf)  # coarse arrival
    send_at = t_q + lam
    m = 0
    for u in range(n):
        if not reached[u] or ttl_rem[u] <= 0:
            continue
        pu = parent[u]
        plist: set = set()
        if strategy == "st1+2" and pu >= 0:
            plist = set(int(x) for x in top.neighbors[pu])
            plist.add(int(pu))
        for v in top.neighbors[u]:
            v = int(v)
            if v == pu:
                continue
            if not reached[v]:
                m += 1          # edge to a peer beyond TTL still costs
                continue
            if strategy == "st1+2" and v in plist:
                continue        # Strategy 2: v provably has Q already
            # Strategy 1: u sends unless it heard v's copy first
            if parent[v] == u:
                m += 1          # tree edge: u is v's first sender
            elif send_at[v] < send_at[u] and (parent[u] == v
                                              or depth[v] <= depth[u]):
                # v sent earlier and u would have received it: skip
                continue
            else:
                m += 1
    return m


# --------------------------------------------------------------------------
# full query simulation
# --------------------------------------------------------------------------

def run_query(top: Topology, origin: int = 0, params: SimParams = SimParams(),
              *, algorithm: str = "fd", strategy: str = "st1+2",
              dynamic: bool = True, lifetime_mean_s: float = float("inf"),
              child_mask: Optional[np.ndarray] = None,
              return_state: bool = False):
    """Simulate one Top-k query.  Returns QueryMetrics (+ state dict).

    algorithm: "fd" | "cn" | "cn_star".
    strategy (fd): "basic" | "st1" | "st1+2" (forward-phase counting).
    dynamic (fd): urgent score-lists + rerouting (§4) on/off.
    child_mask: bool (n,) — peers excluded from forwarding (statistics
    heuristic §3.3); excluded subtrees never receive Q.
    """
    p = params
    rng = np.random.default_rng(p.seed)
    n = top.n
    if p.ttl == 0:
        from repro.p2psim.graph import eccentricity_ttl
        p = dataclasses.replace(p, ttl=eccentricity_ttl(top, origin))

    # ---- reach set (optionally pruned) ---------------------------------
    if child_mask is not None:
        pruned = Topology(n, [top.neighbors[u][child_mask[top.neighbors[u]]]
                              if child_mask[u] or u == origin
                              else np.array([], np.int32)
                              for u in range(n)], top.kind)
        parent, depth, reached = bfs_tree(pruned, origin, p.ttl)
        count_top = pruned
    else:
        parent, depth, reached = bfs_tree(top, origin, p.ttl)
        count_top = top
    idx = np.flatnonzero(reached)
    n_r = len(idx)
    ttl_rem = np.maximum(p.ttl - depth, 0)

    # ---- local data ----------------------------------------------------
    n_tuples = rng.integers(p.tuples_lo, p.tuples_hi + 1, n)
    scores = local_topk_scores(n_tuples, p.k, rng)          # (n, k)
    t_exec = n_tuples * p.exec_s_per_tuple

    # ---- per-edge link draws (tree edges) ------------------------------
    lat_up, bw_up = _draw_link(rng, p, n)       # v -> parent(v)
    lat_dn, bw_dn = _draw_link(rng, p, n)       # parent(v) -> v

    # query arrival times down the tree
    t_q = np.full(n, np.inf)
    t_q[origin] = 0.0
    order = idx[np.argsort(depth[idx])]
    for v in order:
        if v == origin:
            continue
        t_q[v] = t_q[parent[v]] + _link_time(QUERY_BYTES, lat_dn[v], bw_dn[v])
    t_ex_done = t_q + t_exec

    # ---- churn ----------------------------------------------------------
    if math.isinf(lifetime_mean_s):
        death = np.full(n, np.inf)
    else:
        death = rng.exponential(lifetime_mean_s, n)
        death[origin] = np.inf

    met = QueryMetrics(algorithm=algorithm)
    met.n_reached = n_r
    sub = set(int(i) for i in idx)
    met.n_edges_pq = sum(
        1 for u in idx for v in top.neighbors[u] if u < v and int(v) in sub)
    met.avg_degree = float(np.mean([len(top.neighbors[u]) for u in idx]))

    list_bytes = p.k * ENTRY_BYTES_PAPER
    item_sizes = np.maximum(
        rng.normal(p.item_mean_B, p.item_std_B, (n, p.k)), 64.0)

    # ---- CN / CN* baselines --------------------------------------------
    if algorithm in ("cn", "cn_star"):
        lat_o, bw_o = _draw_link(rng, p, n)
        per_peer = (item_sizes[:, :p.k].sum(1) if algorithm == "cn"
                    else np.full(n, float(list_bytes)))
        alive = death > t_ex_done
        senders = idx[alive[idx]]
        senders = senders[senders != origin]
        met.m_fw = forward_messages(count_top, origin, parent, depth,
                                    reached, "basic", p, rng)
        met.b_fw = met.m_fw * QUERY_BYTES
        met.m_bw = len(senders)
        met.b_bw = int(per_peer[senders].sum())
        # originator bandwidth contention: serialized arrival
        own_bw = max(p.bw_mean_Bps, 1.0)
        t_arrive = t_ex_done[senders] + lat_o[senders]
        t_resp = (np.max(t_arrive) if len(senders) else 0.0) \
            + per_peer[senders].sum() / own_bw
        if algorithm == "cn_star":
            # retrieval of actual items still needed
            true_full = np.full((n, p.k), -np.inf)
            true_full[idx] = scores[idx]
            flat = true_full.reshape(-1)
            top_idx = np.argpartition(flat, -p.k)[-p.k:]
            owners = np.unique(top_idx // p.k)
            met.m_rt = 2 * len(owners)
            met.b_rt = int(met.m_rt / 2 * p.request_B
                           + item_sizes.reshape(-1)[top_idx].sum())
            t_resp += 2 * p.latency_mean_s + met.b_rt / own_bw
        met.response_time_s = float(t_resp)
        delivered = np.zeros(n, bool)
        delivered[senders] = True
        delivered[origin] = True
        met.accuracy = _accuracy(scores, idx, delivered, p.k)
        return (met, None) if not return_state else (met, {
            "parent": parent, "depth": depth, "reached": reached})

    # ---- FD: merge-and-backward ----------------------------------------
    met.m_fw = forward_messages(count_top, origin, parent, depth, reached,
                                strategy, p, rng)
    met.b_fw = met.m_fw * QUERY_BYTES

    deadline = t_q + wait_time(ttl_rem, p)
    children: list = [[] for _ in range(n)]
    for v in idx:
        if parent[v] >= 0:
            children[parent[v]].append(int(v))

    # bottom-up: actual send time, delivered lists, merged content
    send_t = np.zeros(n)
    merged_scores = [None] * n       # (k,) arrays
    merged_owner = [None] * n
    delivered = np.zeros(n, bool)    # peer's own top-k reached its parent
    late_urgent: list = []           # (arrival_at_origin_estimate, peer)

    for v in order[::-1]:
        ch = children[v]
        arrivals = []
        for c in ch:
            a = send_t[c] + _link_time(list_bytes, lat_up[c], bw_up[c])
            arrivals.append((a, c))
        own_ready = t_ex_done[v]
        all_in = max([a for a, _ in arrivals], default=0.0)
        s = min(max(own_ready, all_in), max(deadline[v], own_ready))
        if death[v] < s:
            # peer left before sending: its subtree's merged list is lost
            # unless dynamic rerouting saves the CHILDREN's lists (they
            # reroute around the dead parent, §4.2)
            send_t[v] = np.inf
            merged_scores[v] = None
            continue
        send_t[v] = s
        # merge own + children lists that arrived in time (or urgent)
        mats = [scores[v]]
        owners = [np.full(p.k, v, dtype=np.int64)]
        for a, c in arrivals:
            if merged_scores[c] is None:
                # dead child subtree
                if dynamic:
                    for cc in children[c]:
                        if merged_scores[cc] is not None and \
                                send_t[cc] < np.inf:
                            mats.append(merged_scores[cc])
                            owners.append(merged_owner[cc])
                            met.m_bw += 1
                            met.b_bw += list_bytes
                continue
            if a <= s:
                mats.append(merged_scores[c])
                owners.append(merged_owner[c])
            else:
                if dynamic:
                    # urgent list: bubbles without wait; reaches origin
                    hops = depth[v]
                    eta = a + hops * (p.latency_mean_s
                                      + list_bytes / p.bw_mean_Bps)
                    late_urgent.append((eta, c))
                    met.m_bw += int(hops)
                    met.b_bw += int(hops) * list_bytes
        allm = np.concatenate(mats)
        allo = np.concatenate(owners)
        sel = np.argsort(allm)[::-1][:p.k]
        merged_scores[v] = allm[sel]
        merged_owner[v] = allo[sel]
        if v != origin:
            met.m_bw += 1
            met.b_bw += list_bytes

    # urgent lists accepted if they arrive before retrieval starts
    t_merge_done = send_t[origin] + p.merge_s
    extra = []
    for eta, c in late_urgent:
        if eta <= t_merge_done and merged_scores[c] is not None:
            extra.append((merged_scores[c], merged_owner[c]))
    if extra and merged_scores[origin] is not None:
        allm = np.concatenate([merged_scores[origin]]
                              + [e[0] for e in extra])
        allo = np.concatenate([merged_owner[origin]]
                              + [e[1] for e in extra])
        sel = np.argsort(allm)[::-1][:p.k]
        merged_scores[origin] = allm[sel]
        merged_owner[origin] = allo[sel]

    # ---- data retrieval --------------------------------------------------
    final_owners = np.unique(merged_owner[origin])
    alive_owner = final_owners[death[final_owners] > t_merge_done]
    met.m_rt = 2 * len(alive_owner)
    lat_o, bw_o = _draw_link(rng, p, len(final_owners))
    per_owner_counts = np.array(
        [(merged_owner[origin] == o).sum() for o in final_owners])
    fetch_bytes = per_owner_counts * p.item_mean_B
    met.b_rt = int(len(alive_owner) * p.request_B
                   + fetch_bytes[death[final_owners] > t_merge_done].sum())
    t_fetch = (2 * lat_o + (p.request_B + fetch_bytes) / bw_o)
    t_fetch = t_fetch[death[final_owners] > t_merge_done]
    met.response_time_s = float(
        t_merge_done + (t_fetch.max() if len(t_fetch) else 0.0))

    # ---- accuracy ---------------------------------------------------------
    # delivered set: owners present in the final list are by construction
    # delivered; accuracy compares final list vs true top-k of reached set
    true_scores = scores[idx].reshape(-1)
    top_true = np.sort(true_scores)[::-1][:p.k]
    got = np.sort(merged_scores[origin])[::-1]
    # intersection by value (scores a.s. distinct)
    inter = np.intersect1d(top_true, got).size
    # retrieval failures (dead owners) lose their items
    dead_owned = np.isin(merged_owner[origin],
                         final_owners[death[final_owners] <= t_merge_done])
    inter = max(0, inter - int(np.isin(
        merged_scores[origin][dead_owned], top_true).sum()))
    met.accuracy = inter / p.k

    state = {"parent": parent, "depth": depth, "reached": reached,
             "merged_scores": merged_scores, "merged_owner": merged_owner,
             "children": children, "scores": scores}
    return (met, state) if return_state else (met, None)


def _accuracy(scores, idx, delivered, k) -> float:
    true_scores = scores[idx].reshape(-1)
    top_true = np.sort(true_scores)[::-1][:k]
    deliv_idx = idx[delivered[idx]]
    if len(deliv_idx) == 0:
        return 0.0
    got = np.sort(scores[deliv_idx].reshape(-1))[::-1][:k]
    return float(np.intersect1d(top_true, got).size) / k


# --------------------------------------------------------------------------
# statistics heuristic (paper §3.3 + Fig 7)
# --------------------------------------------------------------------------

def run_statistics_heuristic(top: Topology, origin: int,
                             params: SimParams, z: float):
    """Two-round protocol: round 1 full FD gathers per-child best-rank
    stats; round 2 forwards Q only to children whose best past score
    ranked above z*k in the parent's merged list.  Returns
    (metrics_full, metrics_pruned, comm_reduction, accuracy)."""
    met1, st = run_query(top, origin, params, return_state=True)
    parent = st["parent"]
    mo = st["merged_owner"]
    ms = st["merged_scores"]
    children = st["children"]
    n = top.n
    keep = np.ones(n, bool)
    k = params.k
    for v in range(n):
        for c in children[v]:
            if ms[v] is None or ms[c] is None:
                continue
            # best rank of c's subtree contribution within v's merged list
            in_c = np.isin(ms[v], ms[c])
            ranks = np.flatnonzero(in_c)
            best = ranks[0] if len(ranks) else k
            if best >= z * k:
                keep[c] = False
    met2, st2 = run_query(top, origin, params, child_mask=keep,
                          return_state=True)
    # accuracy of round 2 vs round-1 TRUTH (the full reach set) — pruning
    # shrinks P_Q, so met2.accuracy alone would be trivially 1
    reached1 = st["reached"]
    idx1 = np.flatnonzero(reached1)
    true_scores = st["scores"][idx1].reshape(-1)
    top_true = np.sort(true_scores)[::-1][:k]
    got = st2["merged_scores"][origin]
    acc = float(np.intersect1d(top_true, got).size) / k \
        if got is not None else 0.0
    reduction = 1.0 - met2.total_bytes / max(met1.total_bytes, 1)
    return met1, met2, reduction, acc
