from repro.p2psim.graph import Topology, barabasi_albert, waxman  # noqa: F401
from repro.p2psim.metrics import BatchMetrics, QueryMetrics  # noqa: F401
from repro.p2psim.simulate import (  # noqa: F401
    SimParams, run_queries, run_query, run_statistics_heuristic)
