from repro.p2psim.graph import Topology, barabasi_albert, waxman  # noqa: F401
from repro.p2psim.metrics import BatchMetrics, QueryMetrics  # noqa: F401
from repro.p2psim.overlay import (  # noqa: F401
    Overlay, OverlayDelta, SessionEvent, apply_events, available_repairs,
    get_repair, random_session, register_repair)
from repro.p2psim.simulate import (  # noqa: F401
    SimParams, available_placements, build_replica_table, get_placement,
    register_placement, run_queries, run_query, run_query_reference,
    run_statistics_heuristic)
from repro.p2psim.topologies import (  # noqa: F401
    TopologySpec, available_topologies, build_topology, get_topology,
    gnutella, hierarchical, random_regular, register_topology,
    small_world)

# Unified engine surface (ISSUE 2), re-exported for one import path.
# Resolved lazily: repro.engine imports this package's modules, so an
# eager import here would be circular — and DeviceEngine pulls in JAX.
_ENGINE_EXPORTS = ("QuerySpec", "Policy", "TopKResult", "NetworkPlan",
                   "SimEngine", "DeviceEngine", "get_policy",
                   "register_policy", "available_policies",
                   "policy_from_legacy")


def __getattr__(name):
    if name in _ENGINE_EXPORTS:
        import repro.engine as _engine
        return getattr(_engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
