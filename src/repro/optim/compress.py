"""FD top-k gradient compression for the slow cross-pod (DCN) axis.

The paper's insight applied to distributed optimization: never ship the
payload (the dense gradient) across the slow link — ship fixed-size
(score, address) lists and reconstruct.  Mapping:

  peer                  -> pod (the "pod" mesh axis, DCN-connected)
  local query execution -> per-block top-|g| selection (Pallas local_topk)
  score-list            -> (value, global index) k-lists per block
  merge-and-backward    -> ppermute tree / all-gather of k-lists over pods
  data retrieval        -> sparse scatter-add of the k winners (only k
                           values ever cross the DCN, paper's m_rt <= 2k)
  k-inflation (Lemma 4) -> k_eff = k / (1 - p_drop) compensates pods whose
                           contribution is lost to failures
  urgent score-lists    -> error feedback: what wasn't sent this round is
                           accumulated and bubbles up in a later round

Compression ratio per tensor: dense 4*n bytes -> 8*k_eff bytes per pod.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import jaxcompat
from repro.kernels.topk import local_topk


def inflate_k(k: int, p_drop: float) -> int:
    """Paper Lemma 4: request k/(1-P) so that k survive in expectation."""
    if not 0.0 <= p_drop < 1.0:
        raise ValueError(f"p_drop must be in [0,1), got {p_drop}")
    return int(math.ceil(k / (1.0 - p_drop)))


class CompressState(NamedTuple):
    """Error-feedback accumulator, same pytree structure as the grads."""
    ef: object


def compress_init(grads_like) -> CompressState:
    return CompressState(
        jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


# --------------------------------------------------------------------------
# per-tensor local phase (pure — unit-testable without a mesh)
# --------------------------------------------------------------------------

def topk_sparsify(g: jax.Array, k: int, ef: jax.Array):
    """Select the k largest-|.| entries of (g + ef).

    Returns (vals (k,), idx (k,), new_ef) where new_ef holds everything
    NOT selected (error feedback).  vals are the signed values.
    """
    acc = g.astype(jnp.float32).reshape(-1) + ef.reshape(-1)
    mag = jnp.abs(acc)
    _, idx = local_topk(mag, k)
    vals = jnp.take(acc, idx)
    new_ef = acc.at[idx].set(0.0).reshape(ef.shape)
    return vals, idx, new_ef


def sparse_to_dense(vals, idx, n: int):
    return jnp.zeros((n,), jnp.float32).at[idx].add(vals)


# --------------------------------------------------------------------------
# distributed phase: FD merge of sparse contributions over the pod axis
# --------------------------------------------------------------------------

def fd_sparse_allreduce_shard(g, ef, *, k: int, axis_name: str,
                              axis_size: int):
    """Inside shard_map over the DCN axis: approximate mean of ``g``.

    Each pod ships only its k-list; every pod reconstructs the sparse sum.
    Returns (g_hat, new_ef).  Exact when the union of selections covers
    all non-zeros.
    """
    n = g.size
    shape = g.shape
    vals, idx, new_ef = topk_sparsify(g, k, ef)
    # bubble every pod's list to every pod (k*axis_size couples on the wire,
    # vs n dense values for the baseline all-reduce)
    all_v = jax.lax.all_gather(vals, axis_name)        # (P, k)
    all_i = jax.lax.all_gather(idx, axis_name)         # (P, k)
    dense = jnp.zeros((n,), jnp.float32).at[all_i.reshape(-1)].add(
        all_v.reshape(-1))
    g_hat = (dense / axis_size).reshape(shape)
    return g_hat.astype(g.dtype), new_ef


def fd_sparse_allreduce(grads, ef_state: CompressState, mesh,
                        *, axis: str = "pod", k_frac: float = 1e-3,
                        p_drop: float = 0.0):
    """Tree-wise compressed mean over the ``axis`` mesh axis.

    grads leaves must be identical-shaped across pods (e.g. after in-pod
    psum).  k per leaf = inflate_k(ceil(k_frac * n), p_drop).
    """
    from jax.sharding import PartitionSpec as P
    axis_size = mesh.shape[axis]

    def leaf_fn(g, ef):
        k = inflate_k(max(1, int(k_frac * g.size)), p_drop)

        fn = functools.partial(fd_sparse_allreduce_shard, k=k,
                               axis_name=axis, axis_size=axis_size)
        spec = P(*([None] * g.ndim))
        return jaxcompat.shard_map(fn, mesh=mesh, in_specs=(spec, spec),
                                   out_specs=(spec, spec))(g, ef)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef_state.ef)
    out = [leaf_fn(g, e) for g, e in zip(flat_g, flat_e)]
    g_hat = treedef.unflatten([o[0] for o in out])
    new_ef = treedef.unflatten([o[1] for o in out])
    return g_hat, CompressState(new_ef)


def compression_ratio(n: int, k: int, n_pods: int) -> float:
    """Dense all-reduce bytes / FD compressed bytes (per DCN link)."""
    dense = 4 * n * 2 * (n_pods - 1) / n_pods       # ring all-reduce
    sparse = 8 * k * (n_pods - 1)                   # k-lists each way
    return dense / max(sparse, 1)
