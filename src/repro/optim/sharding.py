"""Parameter partition rules: TP over ``model``, FSDP over ``data``/``pod``.

The rules implement the distribution design in DESIGN.md §6:

  * tensor parallelism over the ``model`` axis for every dim that divides
    evenly — attention heads (only when n_heads %% model_size == 0; else the
    attention math is replicated and its weights are ZeRO-sharded), FFN
    hidden, per-expert hidden, RG-LRU width, vocab (embedding + LM head);
  * ZeRO-3-style FSDP over ``("pod", "data")`` on a remaining dim — XLA
    inserts the all-gather-on-use / reduce-scatter-on-grad;
  * everything 1-D (biases, norms, decays) replicated unless model-sharded
    by construction.

``param_specs(params, cfg, mesh)`` returns a PartitionSpec pytree aligned
with the parameter pytree.  Scanned stacks (``groups``) get a leading
``None`` for the layer dim.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

FSDP_AXES = ("pod", "data")
MODEL = "model"


def _axes_size(mesh_shape: dict, axes) -> int:
    return math.prod(mesh_shape.get(a, 1) for a in axes)


def _fit(axes, dim: int, mesh_shape: dict):
    """Return ``axes`` (str | tuple | None) trimmed so dim %% size == 0."""
    if axes is None:
        return None
    if isinstance(axes, str):
        return axes if dim % mesh_shape.get(axes, 1) == 0 else None
    # tuple: drop leading axes until it fits ("pod","data") -> ("data",)
    t = tuple(a for a in axes if a in mesh_shape)
    while t and dim % _axes_size(mesh_shape, t) != 0:
        t = t[1:]
    return t if t else None


def _mk(spec_axes, shape, mesh_shape) -> P:
    fitted = []
    for d, ax in enumerate(spec_axes):
        fitted.append(_fit(ax, shape[d], mesh_shape))
    return P(*fitted)


def fsdp_axes(mesh_shape: dict):
    return tuple(a for a in FSDP_AXES if a in mesh_shape)


def batch_axes(mesh_shape: dict):
    """Axes the global batch is sharded over."""
    return tuple(a for a in FSDP_AXES if a in mesh_shape)


# --------------------------------------------------------------------------
# rule table
# --------------------------------------------------------------------------

def _rules_for(kind: str, name: str, cfg: ModelConfig, mesh_shape: dict,
               ndim: int):
    """Logical axes (pre-fit) for a leaf ``name`` inside a ``kind`` block."""
    msize = mesh_shape.get(MODEL, 1)
    F = fsdp_axes(mesh_shape)
    attn_tp = cfg.n_heads % msize == 0 and cfg.attn_kind != "mla"
    kv_tp = attn_tp and cfg.n_kv_heads % msize == 0

    if kind == "attn":
        if name == "w_q":
            return (F, MODEL) if attn_tp else (F, None)
        if name in ("w_k", "w_v"):
            return (F, MODEL) if kv_tp else (F, None)
        if name == "w_o":
            return (MODEL, F) if attn_tp else (F, None)
        if name == "b_q":
            return (MODEL,) if attn_tp else (None,)
        if name in ("b_k", "b_v"):
            return (MODEL,) if kv_tp else (None,)
        # MLA projections: latent ranks don't head-align; ZeRO only
        if name in ("w_dq", "w_uq", "w_dkv", "w_uk", "w_uv"):
            return (F, None)
    if kind == "rwkv":
        if name in ("w_r", "w_k", "w_v", "w_g", "w_o", "lora_wa"):
            return (F, None)
        if name == "lora_wb":
            return (None, F)
    if kind == "rglru":
        if name in ("w_x", "w_gate"):
            return (F, MODEL)
        if name == "conv_w":
            return (None, MODEL)
        if name in ("conv_b", "lam"):
            return (MODEL,)
        if name in ("w_a", "w_i"):
            return (MODEL, None, None)
        if name == "w_out":
            return (MODEL, F)
    if kind == "ffn":
        if name in ("w_gate", "w_up", "w_k"):      # w_k = rwkv cmix up-proj
            return (F, MODEL)
        if name == "b_up":
            return (MODEL,)
        if name in ("w_down", "w_v"):              # w_v = rwkv cmix down-proj
            return (MODEL, F)
        if name == "w_r":                          # cmix receptance
            return (F, None)
    if kind == "moe":
        # EXPERT-PARALLEL: whole experts sharded over the model axis
        # (E % model == 0 for both assigned MoE archs: 64/16, 32/16).
        # Both operands of the batched expert GEMM are then E-sharded —
        # the GEMMs run with ZERO model-axis communication; the combine
        # pays one (E/TP·C, D) all-gather instead of TP-on-F's (E·C, D)
        # all-reduce (§Perf cell B iteration B4).  ZeRO-1: optimizer
        # state / grad accumulator additionally data-sharded
        # (opt_state_specs).
        if name == "router":
            return (None, None)
        if name in ("w_gate", "w_up", "w_down"):
            return (MODEL, None, None)
    # default: replicate
    return (None,) * ndim


def _classify(path_tokens: list, cfg: ModelConfig):
    """(kind, name, n_scan_dims) for a parameter path."""
    name = path_tokens[-1]
    scan = 1 if "groups" in path_tokens else 0
    # encoder stacks are pure attn; decoder slot kind from the pattern
    if "enc" in path_tokens:
        kind = "attn"
    else:
        kind = "attn"
        if "groups" in path_tokens:
            slot = int(path_tokens[path_tokens.index("groups") + 1])
            kind = cfg.mixer_pattern[slot]
        elif "rem" in path_tokens:
            r = int(path_tokens[path_tokens.index("rem") + 1])
            kind = cfg.mixer_pattern[r % len(cfg.mixer_pattern)]
    if "cross" in path_tokens:
        kind = "attn"
    if "ffn" in path_tokens:
        if "shared" in path_tokens:
            kind = "ffn"
        elif cfg.moe is not None:
            kind = "moe"
        else:
            kind = "ffn"
    if "mixer" not in path_tokens and "ffn" not in path_tokens \
            and "cross" not in path_tokens:
        kind = "top"
    return kind, name, scan


def _top_level_spec(name: str, shape, cfg: ModelConfig, mesh_shape):
    F = fsdp_axes(mesh_shape)
    if name == "embed":
        return _mk((MODEL, F), shape, mesh_shape)
    if name == "w_lm":
        return _mk((F, MODEL), shape, mesh_shape)
    if name == "pos_embed":
        return _mk((None, F), shape, mesh_shape)
    return P(*([None] * len(shape)))


def param_specs(params, cfg: ModelConfig, mesh) -> "jax.tree":
    """PartitionSpec pytree for a parameter pytree."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape)) \
        if hasattr(mesh, "devices") else dict(mesh.shape)

    def one(path, leaf):
        toks = []
        for k in path:
            if hasattr(k, "key"):
                toks.append(str(k.key))
            elif hasattr(k, "idx"):
                toks.append(str(k.idx))
            else:
                toks.append(str(k))
        kind, name, scan = _classify(toks, cfg)
        shape = leaf.shape
        if kind == "top":
            # norms / scalar leaves inside blocks (norm1, ln_x, q_norm, ...)
            if name in ("embed", "w_lm", "pos_embed"):
                return _top_level_spec(name, shape, cfg, mesh_shape)
            return P(*([None] * len(shape)))
        core_shape = shape[scan:]
        axes = _rules_for(kind, name, cfg, mesh_shape, len(core_shape))
        spec = _mk(axes, core_shape, mesh_shape)
        return P(*([None] * scan), *spec)

    return jax.tree_util.tree_map_with_path(one, params)


def opt_state_specs(params, cfg: ModelConfig, mesh):
    """Optimizer-state (and grad-accumulator) specs: parameter specs plus
    ZeRO-1 data-sharding of the MoE expert dims that params keep
    replicated (grads then REDUCE-SCATTER over data once per microbatch
    instead of all-reducing the full expert tensors)."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape)) \
        if hasattr(mesh, "devices") else dict(mesh.shape)
    F = fsdp_axes(mesh_shape)
    base = param_specs(params, cfg, mesh)
    if cfg.moe is None or not F:
        return base

    def fix(path, leaf, spec):
        toks = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        name = toks[-1]
        if "ffn" in toks and "shared" not in toks and \
                name in ("w_gate", "w_up", "w_down"):
            scan = 1 if "groups" in toks else 0
            core = list(spec[scan:])
            # shard the D dim over the data axes (E stays model-sharded)
            d_dim = 1 if name in ("w_gate", "w_up") else 2
            fitted = _fit(F, leaf.shape[scan + d_dim], mesh_shape)
            core[d_dim] = fitted
            return P(*([None] * scan), *core)
        return spec

    return jax.tree_util.tree_map_with_path(fix, params, base)


# --------------------------------------------------------------------------
# decode-state specs (KV caches & recurrent states)
# --------------------------------------------------------------------------

def decode_state_specs(state_like, cfg: ModelConfig, mesh, *, s_max: int):
    """Cache sharding: batch over the data axes; the long sequence (or
    window) dim of attention caches over ``model``.

    Sequence-sharding the KV cache is the TPU-native way to fit 32k-token
    caches per device regardless of head-count divisibility (heads don't
    divide 16 for most assigned archs); the decode attention reduces over
    the sharded seq axis with small (B,H) all-reduces — the FD principle
    (ship reductions, not payloads) applied to attention.
    """
    mesh_shape = dict(mesh.shape)
    baxes = batch_axes(mesh_shape)
    bsize = _axes_size(mesh_shape, baxes)
    msize = mesh_shape.get(MODEL, 1)
    window = cfg.local_window
    seq_dims = {s_max, window, cfg.encoder_seq} - {0}

    def one(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        shape = leaf.shape
        if name == "pos_slots" or (shape and shape[-1:] == shape
                                   and len(shape) == 1):
            d = shape[0]
            return P(MODEL) if d in seq_dims and d % msize == 0 else P()
        if not shape:
            return P()
        spec = [None] * len(shape)
        # batch dim: first dim after the scan-stack dim(s).  Cache leaves
        # under "groups" carry a leading n_groups dim.
        b_dim = 1 if "groups" in [getattr(k, "key", None) for k in path] \
            else 0
        if len(shape) > b_dim and baxes and shape[b_dim] % bsize == 0 \
                and shape[b_dim] >= bsize:
            spec[b_dim] = baxes
        for d in range(b_dim + 1, len(shape)):
            if shape[d] in seq_dims and shape[d] % msize == 0:
                spec[d] = MODEL
                break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, state_like)


# --------------------------------------------------------------------------
# input specs
# --------------------------------------------------------------------------

def input_specs_pytree(batch_like, mesh, *, batch_dim: int = 0):
    """Shard every input leaf's batch dim over the data axes (replicate if
    the batch doesn't divide, e.g. long_500k's global_batch=1)."""
    mesh_shape = dict(mesh.shape)
    baxes = batch_axes(mesh_shape)
    bsize = _axes_size(mesh_shape, baxes)

    def one(leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        if len(shape) > batch_dim and shape[batch_dim] % bsize == 0 and baxes:
            spec[batch_dim] = baxes
        return P(*spec)

    return jax.tree.map(one, batch_like)
