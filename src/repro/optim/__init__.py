from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_lr  # noqa: F401
from repro.optim.sharding import batch_axes, input_specs_pytree, param_specs  # noqa: F401
