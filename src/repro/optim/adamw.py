"""AdamW + cosine schedule + global-norm clipping (optax-free).

Optimizer state is a pytree mirroring the parameters (m, v in f32),
so ``param_specs`` shardings apply verbatim — the optimizer is fully
ZeRO-sharded wherever the parameters are.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    m: object
    v: object


def cosine_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = cosine_lr(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                      # decay matrices, not norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr}
