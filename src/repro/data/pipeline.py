"""Deterministic synthetic token pipeline with a host-sharded loader API.

Real deployments swap ``SyntheticLM`` for a file-backed source; the loader
contract (``__iter__`` of pytrees + ``make_batch_specs`` shardings) is what
the trainer depends on.  Sequences are Zipf-ish token draws with a
repeated-ngram structure so the ~100M-param example can visibly learn
(loss drops well below uniform entropy within a few hundred steps).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class SyntheticLM:
    """Deterministic, restartable synthetic LM data.

    Each sequence: a random "motif" of ``motif_len`` tokens repeated with
    noise — next-token prediction is learnable (copy task) but not trivial.
    """
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    motif_len: int = 32
    noise: float = 0.05
    step: int = 0                      # restart cursor (checkpointable)

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        b, s, v = self.global_batch, self.seq_len, self.vocab_size
        motifs = rng.integers(0, v, (b, self.motif_len))
        reps = -(-s // self.motif_len) + 1
        toks = np.tile(motifs, (1, reps))[:, :s + 1]
        mask = rng.random((b, s + 1)) < self.noise
        toks = np.where(mask, rng.integers(0, v, (b, s + 1)), toks)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.batch_at(self.step)
            self.step += 1


def extra_model_inputs(cfg: ModelConfig, batch_np: dict, *, seed: int = 0,
                       n_vis: int = 256) -> dict:
    """Stub modality frontends: frame/patch embeddings per the assignment."""
    b = batch_np["tokens"].shape[0]
    rng = np.random.default_rng(seed)
    out = dict(batch_np)
    if cfg.is_encoder_decoder:
        out["frames"] = rng.standard_normal(
            (b, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
    if cfg.mrope_sections is not None:
        nv = min(n_vis, batch_np["tokens"].shape[1])
        out["vision_embeds"] = rng.standard_normal(
            (b, nv, cfg.d_model)).astype(np.float32)
    return out


def make_batch_specs(batch: dict, mesh) -> dict:
    """NamedShardings: batch dim over the data axes, rest replicated."""
    from repro.optim.sharding import input_specs_pytree
    specs = input_specs_pytree(
        jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), batch),
        mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def device_put_batch(batch_np: dict, mesh) -> dict:
    shardings = make_batch_specs(batch_np, mesh)
    return jax.tree.map(
        lambda a, s: jax.device_put(jnp.asarray(a), s), batch_np, shardings)
