"""Atomic, sharded, asynchronous checkpointing (npz-per-leaf).

Layout:   <dir>/step_000123/ {tree.json, leaf_00000.npy, ...}
Atomicity: write to ``step_N.tmp`` then ``os.rename`` (POSIX-atomic).
Async:     a snapshot is taken synchronously (device->host copy), the
           file write happens on a daemon thread; ``wait()`` joins.
Keep-N:    oldest complete checkpoints beyond ``keep`` are deleted.
Restore:   leaves are ``jax.device_put`` against target shardings, so a
           checkpoint written on one mesh restores onto any other
           (elastic re-meshing = restore with new shardings).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import numpy as np

import jax


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree: Any, *, blocking: bool = True
         ) -> Optional[threading.Thread]:
    """Write ``tree`` at ``<directory>/step_{step:08d}`` atomically."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    leaves, treedef = _flatten(tree)
    # synchronous device->host snapshot (cheap vs the file write)
    host_leaves = [np.asarray(x) for x in leaves]
    spec = {"n_leaves": len(host_leaves), "treedef": str(treedef),
            "step": step}

    def _write():
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for i, a in enumerate(host_leaves):
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), a)
        with open(os.path.join(tmp, "tree.json"), "w") as f:
            json.dump(spec, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp") \
                and os.path.exists(os.path.join(directory, name, "tree.json")):
            steps.append(int(name[5:]))
    return max(steps) if steps else None


def restore(directory: str, step: int, tree_like: Any,
            shardings: Any = None) -> Any:
    """Load a checkpoint into the structure of ``tree_like``.

    ``shardings``: optional matching pytree of jax.sharding.Sharding —
    pass the CURRENT mesh's shardings to restore elastically onto a
    different device count than the checkpoint was written from.
    """
    path = os.path.join(directory, f"step_{step:08d}")
    leaves_like, treedef = _flatten(tree_like)
    host = [np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
            for i in range(len(leaves_like))]
    for a, like in zip(host, leaves_like):
        if tuple(a.shape) != tuple(np.shape(like)):
            raise ValueError(
                f"checkpoint leaf shape {a.shape} != expected "
                f"{np.shape(like)}")
    if shardings is None:
        out = [jax.device_put(a) for a in host]
    else:
        flat_sh = treedef.flatten_up_to(shardings)
        out = [jax.device_put(a, s) for a, s in zip(host, flat_sh)]
    return treedef.unflatten(out)


class CheckpointManager:
    """save-every-N + keep-last-K + async writes + resume-from-latest."""

    def __init__(self, directory: str, *, save_every: int = 100,
                 keep: int = 3, blocking: bool = False):
        self.directory = directory
        self.save_every = save_every
        self.keep = keep
        self.blocking = blocking
        self._thread: Optional[threading.Thread] = None

    def maybe_save(self, step: int, tree: Any, *, force: bool = False):
        if not force and (step == 0 or step % self.save_every):
            return False
        self.wait()
        self._thread = save(self.directory, step, tree,
                            blocking=self.blocking)
        self._gc()
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        # called right after a new write STARTED: keep (keep-1) existing
        # checkpoints so the in-flight one completes the keep-N set
        if not os.path.isdir(self.directory) or not self.keep:
            return
        steps = sorted(s for s in (
            int(n[5:]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")))
        cut = max(self.keep - 1, 1)
        for s in steps[:-cut]:
            shutil.rmtree(os.path.join(
                self.directory, f"step_{s:08d}"), ignore_errors=True)

    def restore_latest(self, tree_like: Any, shardings: Any = None):
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return step, restore(self.directory, step, tree_like, shardings)
