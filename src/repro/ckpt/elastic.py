"""Elastic re-meshing: restore / reshard state onto a changed device count.

The paper's churn handling at the granularity where TPU systems actually
churn — hosts/pods, between steps.  Checkpoints are device-layout-free
(global np arrays), so elasticity is: build the new mesh, recompute the
partition specs for the same parameter tree, device_put.

``shrink_data_axis`` picks the largest power-of-two data axis that fits
the surviving device count (the model axis is fixed by the parallelism
plan; losing model-axis peers requires restoring on a smaller model axis,
which the same machinery handles as long as divisibility holds).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding

from repro.optim.sharding import param_specs


def largest_pow2_leq(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def make_elastic_mesh(n_devices: int, model_size: int,
                      devices=None) -> Mesh:
    """(data, model) mesh with data = largest power of two that fits."""
    if devices is None:
        devices = jax.devices()[:n_devices]
    data = largest_pow2_leq(len(devices) // model_size)
    if data < 1:
        raise ValueError(
            f"{len(devices)} devices cannot host model axis {model_size}")
    import numpy as np
    arr = np.array(devices[:data * model_size]).reshape(data, model_size)
    return Mesh(arr, ("data", "model"))


def reshard_tree(tree: Any, cfg, new_mesh: Mesh,
                 specs: Optional[Any] = None) -> Any:
    """Move a (possibly host-resident) pytree onto ``new_mesh``."""
    if specs is None:
        specs = param_specs(tree, cfg, new_mesh)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(new_mesh, s)),
        tree, specs)
