from repro.ckpt.checkpoint import (  # noqa: F401
    CheckpointManager, latest_step, restore, save)
from repro.ckpt.elastic import reshard_tree  # noqa: F401
