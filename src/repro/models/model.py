"""Unified model API over every assigned architecture.

One parameter pytree + four entry points, uniform across dense / MoE /
MLA / enc-dec / VLM / SSM / hybrid families:

  * ``init_params(key, cfg, max_seq)``     — full parameter pytree
  * ``loss_fn(params, cfg, batch, ...)``   — next-token CE (vocab-sharded)
  * ``prefill(params, cfg, batch, s_max)`` — build decode caches + last logits
  * ``decode_step(params, cfg, state, tok)`` — one-token step (the dry-run's
    ``serve_step`` lowers this)

Modality frontends are STUBS per the assignment: whisper consumes
precomputed frame embeddings ``(B, enc_seq, d_model)``; qwen2-vl consumes
precomputed patch embeddings scattered over the first ``n_vis`` sequence
slots plus (3, B, S) M-RoPE position streams.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.rope import sinusoidal_embedding

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
          "float16": jnp.float16}


def _dt(cfg: ModelConfig):
    return DTYPES[cfg.param_dtype]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig, *, max_seq: int = 4096) -> dict:
    dtype = _dt(cfg)
    v = cfg.padded_vocab()
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    params: dict = {
        "embed": L.embed_init(ks[0], v, d, dtype),
        "norm_f": L.norm_init(d, cfg.norm, dtype),
        "dec": T.stack_init(ks[1], cfg, dtype, n_layers=cfg.n_layers,
                            pattern=cfg.mixer_pattern,
                            with_cross=cfg.is_encoder_decoder),
    }
    if not cfg.tie_embeddings:
        params["w_lm"] = L.dense_init(ks[2], d, v, dtype)
    if cfg.pos_kind == "learned":
        params["pos_embed"] = (jax.random.normal(
            ks[3], (max_seq, d), jnp.float32) * 0.01).astype(dtype)
    if cfg.is_encoder_decoder:
        params["enc"] = {
            "stack": T.stack_init(ks[4], cfg, dtype,
                                  n_layers=cfg.n_encoder_layers,
                                  pattern=("attn",), with_cross=False),
            "norm_f": L.norm_init(d, cfg.norm, dtype),
        }
    return params


# --------------------------------------------------------------------------
# embeddings / positions / logits
# --------------------------------------------------------------------------

def make_positions(cfg: ModelConfig, batch: int, seq: int, offset=0):
    """(B, S) int32 positions, or (3, B, S) M-RoPE streams (text: all equal)."""
    pos = jnp.arange(seq, dtype=jnp.int32)[None] + offset
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(pos[None], (3, batch, seq))
    return pos


def embed_tokens(params, cfg: ModelConfig, tokens, *, vision_embeds=None,
                 pos_offset=0):
    """tokens: (B, S) int32 -> (B, S, D).  VLM stub: ``vision_embeds``
    (B, n_vis, D) overwrite the first n_vis slots (dynamic_update_slice)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if vision_embeds is not None:
        n_vis = vision_embeds.shape[1]
        if n_vis >= x.shape[1]:
            x = vision_embeds[:, :x.shape[1]].astype(x.dtype)
        else:
            x = jax.lax.dynamic_update_slice(
                x, vision_embeds.astype(x.dtype), (0, 0, 0))
    if cfg.pos_kind == "learned":
        s = x.shape[1]
        pe = jax.lax.dynamic_slice_in_dim(params["pos_embed"],
                                          pos_offset, s, axis=0)
        x = x + pe.astype(x.dtype)
    x = L.constrain(x, L.batch_spec(), None, None)
    return x


def logits_fn(params, cfg: ModelConfig, x):
    """Final norm + LM head.  Logits constrained vocab-sharded over model."""
    h = L.apply_norm(params["norm_f"], x, cfg.norm)
    w = params["embed"].T if cfg.tie_embeddings else params["w_lm"]
    logits = h @ w.astype(h.dtype)
    return L.constrain(logits, L.batch_spec(), None, L.MODEL_AXIS)


# --------------------------------------------------------------------------
# encoder (whisper stub frontend: precomputed frame embeddings)
# --------------------------------------------------------------------------

def encode(params, cfg: ModelConfig, frames, *, q_block=1024, kv_block=1024):
    """frames: (B, enc_seq, D) precomputed embeddings -> (B, enc_seq, D)."""
    b, s, d = frames.shape
    x = frames.astype(_dt(cfg))
    x = x + sinusoidal_embedding(s, d, x.dtype)[None]
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, _, _ = T.stack_apply(params["enc"]["stack"], cfg, x, pattern=("attn",),
                            mode="encode", positions=pos,
                            q_block=q_block, kv_block=kv_block)
    return L.apply_norm(params["enc"]["norm_f"], x, cfg.norm)


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, batch: dict, *, mode: str = "train",
            remat: str = "none", q_block: int = 1024, kv_block: int = 1024):
    """batch keys: tokens (B,S); optional frames (enc-dec), vision_embeds
    (vlm), positions (override).  Returns (logits, caches_or_None, aux)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = batch.get("positions")
    if positions is None:
        positions = make_positions(cfg, b, s)
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = encode(params, cfg, batch["frames"],
                         q_block=q_block, kv_block=kv_block)
    x = embed_tokens(params, cfg, tokens,
                     vision_embeds=batch.get("vision_embeds"))
    x, caches, aux = T.stack_apply(
        params["dec"], cfg, x, pattern=cfg.mixer_pattern, mode=mode,
        positions=positions, enc_out=enc_out, remat=remat,
        q_block=q_block, kv_block=kv_block)
    logits = logits_fn(params, cfg, x)
    return logits, caches, aux


def loss_fn(params, cfg: ModelConfig, batch: dict, *, remat: str = "none",
            q_block: int = 1024, kv_block: int = 1024):
    """Next-token cross-entropy.  labels: (B,S) int32, -1 = ignore.

    The CE is computed against vocab-sharded logits: log-sum-exp and the
    label pick both reduce over the sharded vocab axis (XLA inserts the
    small (B,S) all-reduces — never an all-gather of the logits; this is
    the FD principle applied to the loss).
    """
    logits, _, aux = forward(params, cfg, batch, mode="train", remat=remat,
                             q_block=q_block, kv_block=kv_block)
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)                          # (B,S)
    v = lf.shape[-1]
    onehot = (labels[..., None] ==
              jax.lax.broadcasted_iota(jnp.int32, (1, 1, v), 2))
    picked = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)        # (B,S)
    mask = (labels >= 0).astype(jnp.float32)
    n_tok = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum((lse - picked) * mask) / n_tok
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux, "n_tok": n_tok}


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

class DecodeState(NamedTuple):
    caches: Any           # transformer.stack_caches pytree
    pos: jax.Array        # scalar int32 — next write position


def init_decode_state(cfg: ModelConfig, *, batch: int, s_max: int,
                      cache_dtype=jnp.bfloat16) -> DecodeState:
    caches = T.stack_caches(cfg, n_layers=cfg.n_layers,
                            pattern=cfg.mixer_pattern, batch=batch,
                            s_max=s_max, dtype=cache_dtype,
                            with_cross=cfg.is_encoder_decoder,
                            enc_seq=cfg.encoder_seq)
    return DecodeState(caches, jnp.zeros((), jnp.int32))


def prefill(params, cfg: ModelConfig, batch: dict, *,
            q_block: int = 1024, kv_block: int = 1024):
    """Run the prompt through the stack, building caches.

    Returns (logits_last (B,V), DecodeState).  Note: prefill caches cover
    exactly the prompt; decode-time growth uses pre-sized caches from
    ``init_decode_state`` + ``dynamic_update_slice`` writes instead, so
    serving drivers prefill into a pre-sized state via ``prefill_into``.
    """
    tokens = batch["tokens"]
    logits, caches, _ = forward(params, cfg, batch, mode="prefill",
                                q_block=q_block, kv_block=kv_block)
    state = DecodeState(caches, jnp.asarray(tokens.shape[1], jnp.int32))
    return logits[:, -1], state


def decode_step(params, cfg: ModelConfig, state: DecodeState, tokens,
                *, enc_out=None):
    """One decode step.  tokens: (B, 1) int32.  Returns (logits (B,1,V),
    new state).  Works for every family: attention caches are written at
    ``state.pos``; SSM/hybrid states advance in O(1)."""
    b = tokens.shape[0]
    positions = make_positions(cfg, b, 1, offset=state.pos)
    x = embed_tokens(params, cfg, tokens, pos_offset=state.pos)
    x, caches, _ = T.stack_apply(
        params["dec"], cfg, x, pattern=cfg.mixer_pattern, mode="decode",
        positions=positions, caches=state.caches, cache_pos=state.pos,
        enc_out=enc_out)
    logits = logits_fn(params, cfg, x)
    return logits, DecodeState(caches, state.pos + 1)


# --------------------------------------------------------------------------
# parameter counting helper (cross-checks cfg.param_count against the tree)
# --------------------------------------------------------------------------

def count_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
