"""RWKV-6 "Finch" time mixing: linear attention with data-dependent
per-channel decay (arXiv:2404.05892).

Two equivalent evaluators:
  * ``rwkv6_scan``     — naive per-token recurrence (oracle + decode step)
  * ``rwkv6_chunked``  — chunkwise-parallel form used for train/prefill.

The chunked form is numerically EXACT (not a descale approximation): all
intra-chunk decay factors are exp of *non-positive* sums computed by
cumsum differences, and cross-chunk information flows through the f32
state, so no unbounded exp ever appears.  Chunk size trades VMEM
((B,C,C,H,K) transient) against sequential depth.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


# --------------------------------------------------------------------------
# core recurrence
# --------------------------------------------------------------------------

def rwkv6_scan(r, k, v, w, u, s0):
    """Naive recurrence.  r,k,v,w: (B,T,H,K); u: (H,K); s0: (B,H,K,V).

    o_t = r_t . (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    Returns (o (B,T,H,V), s_T).
    """
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                                  # (B,H,K)
        kv = k_t[..., :, None] * v_t[..., None, :]                # (B,H,K,V)
        o = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[..., None] * kv)
        s = w_t[..., None] * s + kv
        return s, o

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, w))
    s_t, o = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return jnp.moveaxis(o, 0, 1), s_t


def rwkv6_chunked(r, k, v, w, u, s0, chunk: int = 16):
    """Chunkwise-parallel evaluation, exact (see module docstring)."""
    b, t, h, kk = r.shape
    vv = v.shape[-1]
    c = min(chunk, t)
    pad = (-t) % c
    if pad:
        def zp(a):
            return jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zp(r), zp(k), zp(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    n = (t + pad) // c

    f32 = jnp.float32
    rc = r.astype(f32).reshape(b, n, c, h, kk)
    kc = k.astype(f32).reshape(b, n, c, h, kk)
    vc = v.astype(f32).reshape(b, n, c, h, vv)
    lw = jnp.log(jnp.clip(w.astype(f32), 1e-12, 1.0)).reshape(b, n, c, h, kk)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def chunk_step(s, inp):
        # checkpointed: the (B,C,C,H,K) intra-chunk decay tensor is
        # recomputed in the backward pass instead of being saved per
        # chunk — O(T·C·H·K) residual memory would otherwise dominate
        # the whole training step (72 GiB/dev at C=128 on rwkv6-3b).
        r_c, k_c, v_c, lw_c = inp             # (B,C,H,K) / (B,C,H,V)
        cum = jnp.cumsum(lw_c, axis=1)        # inclusive  (B,C,H,K)
        cumx = cum - lw_c                     # exclusive-before-i

        # inter-chunk: o_i += (r_i * exp(cumx_i)) . S
        rs = r_c * jnp.exp(cumx)
        o = jnp.einsum("bchk,bhkv->bchv", rs, s)

        # intra-chunk (j < i): exp(cumx_i - cum_j) FACTORIZES as
        # exp(cumx_i - m) * exp(m - cum_j), so the (B,C,C,H,K) decay
        # tensor never materializes — two exps + one batched GEMM.
        # m is a per-(b,h,k) chunk center keeping both exponents within
        # half the chunk's decay range (f32-safe: |exp| <= e^(range/2)).
        mid = 0.5 * (cum[:, :1] + cum[:, -1:])           # (B,1,H,K)
        qd = r_c * jnp.exp(cumx - mid)                   # (B,C,H,K)
        kd2 = k_c * jnp.exp(mid - cum)                   # (B,C,H,K)
        a = jnp.einsum("bihk,bjhk->bhij", qd, kd2)       # (B,H,C,C)
        mask = (jnp.arange(c)[:, None] > jnp.arange(c)[None, :])
        a = a * mask[None, None]
        o = o + jnp.einsum("bhij,bjhv->bihv", a, v_c)

        # current-token bonus: o_i += (r_i * u) . (k_i v_i^T)
        au = jnp.einsum("bihk,bihk->bih", r_c * u[None, None], k_c)
        o = o + au[..., None] * v_c

        # state: S' = diag(exp(cum_C)) S + sum_j (k_j exp(cum_C - cum_j)) v_j^T
        tot = cum[:, -1]                                  # (B,H,K)
        kd = k_c * jnp.exp(tot[:, None] - cum)
        s = jnp.exp(tot)[..., None] * s + jnp.einsum("bjhk,bjhv->bhkv", kd, v_c)
        return s, o

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rc, kc, vc, lw))
    s_t, o = jax.lax.scan(chunk_step, s0.astype(f32), xs)
    o = jnp.moveaxis(o, 0, 1).reshape(b, n * c, h, vv)[:, :t]
    return o, s_t


# --------------------------------------------------------------------------
# the full time-mix layer
# --------------------------------------------------------------------------

def rwkv_init(key, cfg, dtype):
    d = cfg.d_model
    kdim = cfg.recurrent.rwkv_head_dim
    h = d // kdim
    ks = jax.random.split(key, 8)
    # decay init: slow->fast across channels (rwkv convention)
    ratio = jnp.arange(d, dtype=jnp.float32) / max(d - 1, 1)
    decay_base = -6.0 + 5.0 * ratio ** 0.7
    u = 0.5 * (1.0 - ratio)
    return {
        "mu": jnp.full((5, d), 0.5, dtype),        # r,k,v,w,g token-shift mixes
        "w_r": dense_init(ks[0], d, d, dtype),
        "w_k": dense_init(ks[1], d, d, dtype),
        "w_v": dense_init(ks[2], d, d, dtype),
        "w_g": dense_init(ks[3], d, d, dtype),
        "w_o": dense_init(ks[4], d, d, dtype),
        "decay_base": decay_base.astype(jnp.float32),
        "lora_wa": dense_init(ks[5], d, 32, dtype, scale=0.01),
        "lora_wb": dense_init(ks[6], 32, d, dtype, scale=0.01),
        "u": u.astype(jnp.float32),
        "ln_x": {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
    }


def apply_rwkv(params, x, cfg, *, state, x_prev, chunk: int | None = None):
    """RWKV-6 time mix.  x: (B,S,D); state: (B,H,K,V) f32; x_prev: (B,1,D).

    Returns (y, (state', x_last)).  Decode is just S == 1 (scan path).
    """
    b, s, d = x.shape
    kdim = cfg.recurrent.rwkv_head_dim
    h = d // kdim
    chunk = chunk or cfg.recurrent.chunk_size

    shifted = jnp.concatenate([x_prev.astype(x.dtype), x[:, :-1]], axis=1)
    mu = params["mu"].astype(x.dtype)

    def mix(i):
        return x * mu[i] + shifted * (1 - mu[i])

    xr, xk, xv, xw, xg = (mix(i) for i in range(5))
    r = (xr @ params["w_r"]).reshape(b, s, h, kdim)
    k = (xk @ params["w_k"]).reshape(b, s, h, kdim)
    v = (xv @ params["w_v"]).reshape(b, s, h, kdim)
    g = jax.nn.silu(xg @ params["w_g"])

    # data-dependent decay (Finch): w = exp(-exp(base + lora(xw)))
    adj = jnp.tanh(xw @ params["lora_wa"]) @ params["lora_wb"]
    logit = params["decay_base"][None, None] + adj.astype(jnp.float32)
    w = jnp.exp(-jnp.exp(logit)).reshape(b, s, h, kdim)

    u = params["u"].reshape(h, kdim)
    if s == 1:
        o, state = rwkv6_scan(r, k, v, w, u, state)
    else:
        o, state = rwkv6_chunked(r, k, v, w, u, state, chunk)

    # per-head group norm
    mean = jnp.mean(o, axis=-1, keepdims=True)
    var = jnp.var(o, axis=-1, keepdims=True)
    o = (o - mean) * jax.lax.rsqrt(var + 1e-5)
    o = o.reshape(b, s, d).astype(x.dtype)
    o = o * params["ln_x"]["scale"] + params["ln_x"]["bias"]

    y = (o * g) @ params["w_o"]
    return y, (state, x[:, -1:].astype(jnp.float32))


def rwkv_init_state(cfg, batch: int):
    kdim = cfg.recurrent.rwkv_head_dim
    h = cfg.d_model // kdim
    return (jnp.zeros((batch, h, kdim, kdim), jnp.float32),
            jnp.zeros((batch, 1, cfg.d_model), jnp.float32))
