"""Transformer stacks: blocks, scan-over-layers, pattern groups, remat.

Layers are grouped by ``cfg.mixer_pattern``: a scan runs over whole groups
(homogeneous pytrees), a remainder (n_layers % len(pattern)) is unrolled.
Caches follow the same (groups-stacked, remainder-list) structure.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import griffin, moe as moe_mod, rwkv
from repro.models import layers as L


# --------------------------------------------------------------------------
# single block
# --------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig, kind: str, dtype, with_cross: bool):
    d = cfg.d_model
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p: dict = {"norm1": L.norm_init(d, cfg.norm, dtype),
               "norm2": L.norm_init(d, cfg.norm, dtype)}
    if kind == "attn":
        p["mixer"] = (attn.mla_init(k1, cfg, dtype)
                      if cfg.attn_kind == "mla" else attn.gqa_init(k1, cfg, dtype))
    elif kind == "rwkv":
        p["mixer"] = rwkv.rwkv_init(k1, cfg, dtype)
    elif kind == "rglru":
        p["mixer"] = griffin.griffin_init(k1, cfg, dtype)
    else:
        raise ValueError(kind)
    if with_cross:
        p["norm_c"] = L.norm_init(d, cfg.norm, dtype)
        p["cross"] = attn.gqa_init(k2, cfg, dtype)
    if cfg.moe is not None:
        p["ffn"] = moe_mod.moe_init(k3, cfg, dtype)
    elif cfg.act == "rwkv_channel_mix":
        p["ffn"] = L.rwkv_cmix_init(k3, cfg.d_model, cfg.d_ff, dtype)
    else:
        p["ffn"] = L.ffn_init(k3, d, cfg.d_ff, cfg.act, dtype)
    return p


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, s_max: int,
                     dtype, with_cross: bool, enc_seq: int = 0):
    """Zero/empty caches for decode."""
    hd, nkv = cfg.resolved_head_dim, cfg.n_kv_heads
    c: dict = {}
    if kind == "attn":
        if cfg.attn_kind == "mla":
            m = cfg.mla
            c["self"] = attn.MLACache(
                jnp.zeros((batch, s_max, m.kv_lora_rank), dtype),
                jnp.zeros((batch, s_max, m.qk_rope_dim), dtype))
        elif cfg.local_window:
            w = min(cfg.local_window, s_max)
            c["self"] = attn.WindowKVCache(
                jnp.zeros((batch, w, nkv, hd), dtype),
                jnp.zeros((batch, w, nkv, hd), dtype),
                jnp.full((w,), -1, jnp.int32))
        else:
            c["self"] = attn.KVCache(
                jnp.zeros((batch, s_max, nkv, hd), dtype),
                jnp.zeros((batch, s_max, nkv, hd), dtype))
    elif kind == "rwkv":
        kd = cfg.recurrent.rwkv_head_dim
        h = cfg.d_model // kd
        c["state"] = jnp.zeros((batch, h, kd, kd), jnp.float32)
        c["xp_t"] = jnp.zeros((batch, 1, cfg.d_model), jnp.float32)
        c["xp_c"] = jnp.zeros((batch, 1, cfg.d_model), jnp.float32)
    elif kind == "rglru":
        lw = cfg.recurrent.lru_width or cfg.d_model
        c["h"] = jnp.zeros((batch, lw), jnp.float32)
        c["conv"] = jnp.zeros((batch, cfg.recurrent.conv_width - 1, lw),
                              jnp.float32)
    if with_cross:
        c["cross"] = attn.KVCache(
            jnp.zeros((batch, enc_seq, nkv, hd), dtype),
            jnp.zeros((batch, enc_seq, nkv, hd), dtype))
    return c


def block_apply(params, cfg: ModelConfig, kind: str, x, *, positions, mode,
                cache: Optional[dict] = None, cache_pos=None, enc_out=None,
                q_block: int = 1024, kv_block: int = 1024):
    """Apply one block.  Returns (x', cache', aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {} if cache is not None or mode == "prefill" else None
    h = L.apply_norm(params["norm1"], x, cfg.norm)

    if kind == "attn":
        window = cfg.local_window
        if mode == "decode":
            if cfg.attn_kind == "mla":
                y, c = attn.mla_attention(params["mixer"], h, cfg,
                                          positions=positions, mode="decode",
                                          cache=cache["self"],
                                          cache_pos=cache_pos)
            elif window:
                y, c = attn.gqa_decode_window(params["mixer"], h, cfg,
                                              cache=cache["self"],
                                              cache_pos=cache_pos,
                                              positions=positions)
            else:
                y, c = attn.gqa_decode(params["mixer"], h, cfg,
                                       cache=cache["self"],
                                       cache_pos=cache_pos,
                                       positions=positions)
        else:
            if cfg.attn_kind == "mla":
                y, c = attn.mla_attention(params["mixer"], h, cfg,
                                          positions=positions, mode=mode,
                                          q_block=q_block, kv_block=kv_block)
            else:
                y, c = attn.gqa_attention(params["mixer"], h, cfg,
                                          positions=positions, mode=mode,
                                          window=window, q_block=q_block,
                                          kv_block=kv_block)
        if new_cache is not None and c is not None:
            new_cache["self"] = c
    elif kind == "rwkv":
        if cache is not None:
            st, xp = cache["state"], cache["xp_t"]
        else:
            st, xp = rwkv.rwkv_init_state(cfg, x.shape[0])
        y, (st2, xp2) = rwkv.apply_rwkv(params["mixer"], h, cfg,
                                        state=st, x_prev=xp)
        if new_cache is not None:
            new_cache["state"], new_cache["xp_t"] = st2, xp2
    elif kind == "rglru":
        if cache is not None:
            st = (cache["h"], cache["conv"])
        else:
            st = griffin.griffin_init_state(cfg, x.shape[0])
        y, st2 = griffin.apply_griffin(params["mixer"], h, cfg, state=st)
        if new_cache is not None:
            new_cache["h"], new_cache["conv"] = st2
    else:
        raise ValueError(kind)
    x = x + y

    if "cross" in params:
        hc = L.apply_norm(params["norm_c"], x, cfg.norm)
        if mode == "decode":
            yc, cc = attn.cross_decode(params["cross"], hc, cfg,
                                       cache=cache["cross"])
        else:
            yc, cc = attn.gqa_attention(params["cross"], hc, cfg,
                                        positions=positions, mode=mode,
                                        kv_source=enc_out, q_block=q_block,
                                        kv_block=kv_block)
        if new_cache is not None and cc is not None:
            new_cache["cross"] = cc
        x = x + yc

    h = L.apply_norm(params["norm2"], x, cfg.norm)
    if cfg.moe is not None:
        y, aux = moe_mod.apply_moe(params["ffn"], h, cfg)
    elif cfg.act == "rwkv_channel_mix":
        xp = cache["xp_c"] if cache is not None else \
            jnp.zeros((x.shape[0], 1, cfg.d_model), jnp.float32)
        y, xp2 = L.apply_rwkv_cmix(params["ffn"], h, xp)
        if new_cache is not None:
            new_cache["xp_c"] = xp2
    else:
        y = L.apply_ffn(params["ffn"], h, cfg.act)
    x = x + y
    x = L.constrain(x, L.batch_spec(), None, None)
    return x, new_cache, aux


# --------------------------------------------------------------------------
# stack (scan over pattern groups + unrolled remainder)
# --------------------------------------------------------------------------

def stack_layout(cfg: ModelConfig, n_layers: int, pattern: tuple):
    glen = len(pattern)
    return n_layers // glen, n_layers % glen


def stack_init(key, cfg: ModelConfig, dtype, *, n_layers: int,
               pattern: tuple, with_cross: bool):
    n_groups, rem = stack_layout(cfg, n_layers, pattern)
    keys = jax.random.split(key, n_groups * len(pattern) + rem)
    params: dict = {"groups": [], "rem": []}
    i = 0
    for slot, kind in enumerate(pattern):
        slot_keys = keys[i:i + n_groups]
        i += n_groups
        init_one = functools.partial(block_init, cfg=cfg, kind=kind,
                                     dtype=dtype, with_cross=with_cross)
        params["groups"].append(jax.vmap(lambda k: init_one(k))(slot_keys)
                                if n_groups else {})
    for r in range(rem):
        kind = pattern[r % len(pattern)]
        params["rem"].append(block_init(keys[i], cfg, kind, dtype, with_cross))
        i += 1
    return params


def stack_caches(cfg: ModelConfig, *, n_layers: int, pattern: tuple,
                 batch: int, s_max: int, dtype, with_cross: bool,
                 enc_seq: int = 0):
    n_groups, rem = stack_layout(cfg, n_layers, pattern)
    caches: dict = {"groups": [], "rem": []}
    for kind in pattern:
        one = init_block_cache(cfg, kind, batch, s_max, dtype, with_cross,
                               enc_seq)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape).copy(), one)
        caches["groups"].append(stacked)
    for r in range(rem):
        kind = pattern[r % len(pattern)]
        caches["rem"].append(
            init_block_cache(cfg, kind, batch, s_max, dtype, with_cross,
                             enc_seq))
    return caches


def stack_apply(params, cfg: ModelConfig, x, *, pattern: tuple, mode: str,
                positions, caches=None, cache_pos=None, enc_out=None,
                remat: str = "none", q_block: int = 1024,
                kv_block: int = 1024):
    """Run the stack.  Returns (x, caches', aux_sum)."""
    n_groups = jax.tree.leaves(params["groups"][0])[0].shape[0] \
        if params["groups"] and jax.tree.leaves(params["groups"][0]) else 0
    with_caches = caches is not None
    build_caches = with_caches or mode == "prefill"

    def group_body(x, group_params, group_caches):
        aux = jnp.zeros((), jnp.float32)
        new_caches = []
        for slot, kind in enumerate(pattern):
            c = group_caches[slot] if with_caches else None
            x, c2, a = block_apply(group_params[slot], cfg, kind, x,
                                   positions=positions, mode=mode, cache=c,
                                   cache_pos=cache_pos, enc_out=enc_out,
                                   q_block=q_block, kv_block=kv_block)
            aux = aux + a
            new_caches.append(c2)
        return x, new_caches, aux

    if remat == "full":
        group_body = jax.checkpoint(group_body)
    elif remat == "dots":
        group_body = jax.checkpoint(
            group_body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    if n_groups:
        if with_caches:
            def scan_fn(x, sliced):
                g_params, g_caches = sliced
                x, new_c, aux = group_body(x, g_params, g_caches)
                return x, (new_c, aux)
            x, (new_group_caches, auxs) = jax.lax.scan(
                scan_fn, x, (params["groups"], caches["groups"]))
        else:
            def scan_fn(x, g_params):
                x, new_c, aux = group_body(x, g_params, None)
                return x, (new_c, aux)
            # train: new_c is None (empty pytree); prefill: stacked caches
            x, (new_group_caches, auxs) = jax.lax.scan(
                scan_fn, x, params["groups"])
        aux_total = jnp.sum(auxs)
    else:
        if with_caches:
            new_group_caches = caches["groups"]
        elif build_caches:
            new_group_caches = [{} for _ in pattern]
        else:
            new_group_caches = None
        aux_total = jnp.zeros((), jnp.float32)

    new_rem = []
    for r, bp in enumerate(params["rem"]):
        kind = pattern[r % len(pattern)]
        c = caches["rem"][r] if with_caches else None
        x, c2, a = block_apply(bp, cfg, kind, x, positions=positions,
                               mode=mode, cache=c, cache_pos=cache_pos,
                               enc_out=enc_out, q_block=q_block,
                               kv_block=kv_block)
        aux_total = aux_total + a
        new_rem.append(c2)

    new_caches = ({"groups": new_group_caches, "rem": new_rem}
                  if build_caches else None)
    return x, new_caches, aux_total
