"""Top-k routed Mixture-of-Experts FFN.

Router top-k is the paper's "local query execution" (a per-token local
top-k over expert scores — no communication), and the dispatch keeps only
the routed (promising) experts, the paper's statistics-heuristic analogue.

Implementation: sort-based token grouping + ``jax.lax.ragged_dot`` grouped
GEMMs — exact (dropless), static shapes, differentiable.  Expert weights
are tensor-sharded on the per-expert hidden dim (d_expert over the model
axis), so dispatch needs no all-to-all; the combine is the same psum the
dense FFN TP already pays.  (EP + all-to-all is a §Perf variant.)
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import (MODEL_AXIS, batch_spec, constrain,
                                 dense_init)
from repro.models.layers import model_size as _model_size


def moe_init(key, cfg, dtype):
    e = cfg.moe
    d, f = cfg.d_model, e.d_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, e.n_experts, jnp.float32, scale=0.02),
        "w_gate": (jax.random.normal(ks[1], (e.n_experts, d, f), jnp.float32)
                   * d ** -0.5).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e.n_experts, d, f), jnp.float32)
                 * d ** -0.5).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e.n_experts, f, d), jnp.float32)
                   * f ** -0.5).astype(dtype),
    }
    if e.n_shared_experts:
        fs = f * e.n_shared_experts
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(kss[0], d, fs, dtype),
            "w_up": dense_init(kss[1], d, fs, dtype),
            "w_down": dense_init(kss[2], fs, d, dtype, scale=fs ** -0.5),
        }
    return p


def apply_moe(params, x, cfg):
    """x: (B, S, D) -> (y, aux_loss).

    aux_loss is the Switch/GShard load-balance loss (mean fraction *
    mean gate mass per expert * n_experts).

    Distribution: routing is token-independent, so the sort-based
    dispatch runs *per data shard* inside a partial-manual shard_map —
    the global argsort would otherwise force an all-gather of every
    token (the CN anti-pattern).  The model axis stays automatic: expert
    weights keep their F-dim tensor sharding inside the region.
    """
    from repro.models.layers import _mesh_axis_names, BATCH_AXES
    names = _mesh_axis_names()
    manual = tuple(a for a in BATCH_AXES if a in names)
    if manual:
        import math as _math
        from repro import jaxcompat
        bsize = _math.prod(jaxcompat.mesh_shape()[a] for a in manual)
        if x.shape[0] % bsize != 0:
            manual = ()
    if not manual:
        return _moe_local(params, x, cfg)
    return _moe_dispatch_outside(params, x, cfg, manual)


def _moe_dispatch_outside(params, x, cfg, manual):
    """Distributed MoE with the expert GEMMs OUTSIDE the manual region.

    Only the (weight-free) dispatch and combine run per data shard inside
    shard_map; the batched expert GEMMs are ordinary pjit einsums whose
    gradients flow through standard SPMD paths — ONE reduce-scatter of
    the expert-weight grads per microbatch into the data-sharded
    accumulator (ZeRO-1), instead of a full f32 all-reduce per layer per
    microbatch (the v1 design measured 1 TB/device/step on
    moonshot × train_4k; see EXPERIMENTS.md §Perf).
    """
    import math as _math
    from jax.sharding import PartitionSpec as P
    from repro import jaxcompat
    e = cfg.moe
    b, s, d = x.shape
    mesh = jaxcompat.current_mesh()
    bsize = _math.prod(dict(mesh.shape)[a] for a in manual)
    t_local = (b // bsize) * s
    k = e.top_k
    cap = int(_math.ceil(t_local * k / e.n_experts * e.capacity_factor))

    def dispatch(router, xl):
        bl, sl, _ = xl.shape
        tl = bl * sl
        xf = xl.reshape(tl, d)
        logits = xf.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
        frac = jnp.mean(jax.nn.one_hot(expert_ids, e.n_experts,
                                       dtype=jnp.float32), axis=(0, 1))
        mass = jnp.mean(probs, axis=0)
        aux = e.n_experts * jnp.sum(frac * mass) * e.router_aux_coef
        flat_exp = expert_ids.reshape(-1)
        order = jnp.argsort(flat_exp)
        inv_order = jnp.argsort(order)
        tok_idx = order // k
        sorted_exp = jnp.take(flat_exp, order)
        counts = jnp.bincount(flat_exp, length=e.n_experts)
        starts = jnp.cumsum(counts) - counts
        rank = jnp.arange(tl * k) - jnp.take(starts, sorted_exp)
        slot = jnp.where(rank < cap, sorted_exp * cap + rank,
                         e.n_experts * cap)
        buf = jnp.zeros((e.n_experts * cap, d), xl.dtype)
        buf = buf.at[slot].set(jnp.take(xf, tok_idx, axis=0), mode="drop")
        slot_of_flat = jnp.take(slot, inv_order)
        return (buf.reshape(e.n_experts, cap, d), gate_vals,
                slot_of_flat, jax.lax.pmean(aux, manual))

    buf, gates, slot_of_flat, aux = jaxcompat.shard_map(
        dispatch, mesh=mesh,
        in_specs=(P(), P(manual, None, None)),
        out_specs=(P(None, manual, None), P(manual, None), P(manual), P()),
        axis_names=set(manual))(params["router"], x)

    # ---- batched expert GEMMs under plain pjit, EXPERT-PARALLEL ---------
    # buf arrives model-replicated from the dispatch region; constraining
    # it E-over-model is a local slice (free).  The GEMMs are then fully
    # local per model rank (both operands E-sharded).  ye is re-replicated
    # over model for the combine gather — ONE all-gather of the rank's
    # (E/TP · C, D) slice, ~32x less operand traffic than the TP-on-F
    # combine all-reduce this replaced (§Perf cell B, iteration B4).
    # [Iteration B3's explicit AG/psum_scatter shard_map was refuted:
    #  partial-manual in_specs reshard unmentioned auto dims.]
    ep = e.n_experts % _model_size() == 0 and _model_size() > 1
    if ep:
        buf = constrain(buf, MODEL_AXIS, batch_spec(), None)
    h = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    h = jax.nn.silu(h) * u
    if ep:
        h = constrain(h, MODEL_AXIS, batch_spec(), None)
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    ye = constrain(ye, None, batch_spec(), None)

    def combine(y_local, gates_l, slot_l):
        tl = gates_l.shape[0]
        y_flat = y_local.reshape(e.n_experts * cap, d)
        kept = (slot_l < e.n_experts * cap)[:, None]
        y_tok = jnp.take(y_flat, jnp.minimum(slot_l,
                                             e.n_experts * cap - 1), axis=0)
        y_tok = jnp.where(kept, y_tok, 0).reshape(tl, k, d)
        y = jnp.sum(y_tok * gates_l[..., None].astype(y_tok.dtype), axis=1)
        return y.reshape(tl // s, s, d)

    y = jaxcompat.shard_map(
        combine, mesh=mesh,
        in_specs=(P(None, manual, None), P(manual, None), P(manual)),
        out_specs=P(manual, None, None),
        axis_names=set(manual))(ye, gates, slot_of_flat)

    if e.n_shared_experts:
        sp = params["shared"]
        xf = x.reshape(b * s, d)
        hs = jax.nn.silu(xf @ sp["w_gate"]) * (xf @ sp["w_up"])
        y = y + (hs @ sp["w_down"]).reshape(b, s, d)

    return y.astype(x.dtype), aux


def _moe_local(params, x, cfg, *, impl: str = "capacity"):
    """Single-shard MoE.  impl:

    * "capacity" (default) — sort-based dispatch into a static (E*C, D)
      buffer + batched per-expert einsum GEMMs.  Static shapes, partitions
      cleanly (the einsum's F dim carries the model-axis sharding), and —
      unlike lax.ragged_dot — does NOT lower to a dense (E, T*k, D)
      blow-up on backends without native grouped GEMM.  Tokens beyond
      capacity C = ceil(T*k/E * capacity_factor) are dropped (GShard
      semantics).
    * "ragged" — exact dropless lax.ragged_dot grouped GEMM (TPU path).
    """
    e = cfg.moe
    b, s, d = x.shape
    t = b * s
    k = e.top_k
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ params["router"])       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)            # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balance aux loss
    frac = jnp.mean(jax.nn.one_hot(expert_ids, e.n_experts, dtype=jnp.float32),
                    axis=(0, 1))
    mass = jnp.mean(probs, axis=0)
    aux = e.n_experts * jnp.sum(frac * mass) * e.router_aux_coef

    # --- dispatch: sort (token, slot) pairs by expert id -----------------
    flat_exp = expert_ids.reshape(-1)                          # (T*k,)
    order = jnp.argsort(flat_exp)                              # static shape
    inv_order = jnp.argsort(order)
    tok_idx = order // k                                       # flat j -> token

    if impl == "ragged":
        xin = jnp.take(xf, tok_idx, axis=0)                    # (T*k, D)
        group_sizes = jnp.bincount(flat_exp, length=e.n_experts)
        h = jax.lax.ragged_dot(xin, params["w_gate"], group_sizes)
        u = jax.lax.ragged_dot(xin, params["w_up"], group_sizes)
        h = jax.nn.silu(h) * u
        h = constrain(h, batch_spec(), MODEL_AXIS)
        yo = jax.lax.ragged_dot(h, params["w_down"], group_sizes)
        yo = jnp.take(yo, inv_order, axis=0).reshape(t, k, d)
        y = jnp.sum(yo * gate_vals[..., None].astype(yo.dtype), axis=1)
    else:
        cap = int(math.ceil(t * k / e.n_experts * e.capacity_factor))
        sorted_exp = jnp.take(flat_exp, order)                 # (T*k,)
        counts = jnp.bincount(flat_exp, length=e.n_experts)    # (E,)
        starts = jnp.cumsum(counts) - counts                   # (E,)
        rank = jnp.arange(t * k) - jnp.take(starts, sorted_exp)
        slot = jnp.where(rank < cap, sorted_exp * cap + rank,
                         e.n_experts * cap)                    # OOB -> drop
        buf = jnp.zeros((e.n_experts * cap, d), xf.dtype)
        buf = buf.at[slot].set(jnp.take(xf, tok_idx, axis=0), mode="drop")
        bufe = buf.reshape(e.n_experts, cap, d)
        h = jnp.einsum("ecd,edf->ecf", bufe, params["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", bufe, params["w_up"])
        h = jax.nn.silu(h) * u
        h = constrain(h, None, None, MODEL_AXIS)
        ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
        y_buf = ye.reshape(e.n_experts * cap, d)
        slot_of_flat = jnp.take(slot, inv_order)               # (T*k,)
        kept = (slot_of_flat < e.n_experts * cap)[:, None]
        y_flat = jnp.take(y_buf, jnp.minimum(
            slot_of_flat, e.n_experts * cap - 1), axis=0)
        y_flat = jnp.where(kept, y_flat, 0)
        yo = y_flat.reshape(t, k, d)
        y = jnp.sum(yo * gate_vals[..., None].astype(yo.dtype), axis=1)

    if e.n_shared_experts:
        sp = params["shared"]
        hs = jax.nn.silu(xf @ sp["w_gate"]) * (xf @ sp["w_up"])
        y = y + hs @ sp["w_down"]

    return y.reshape(b, s, d).astype(x.dtype), aux
