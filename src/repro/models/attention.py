"""Attention token mixers: GQA (+QKV bias, M-RoPE, sliding window), MLA,
and encoder/cross attention — all built on one blocked online-softmax core
(pure-JAX flash) so 32k-token prefill compiles with bounded memory.

Shapes follow (B, S, H, Dh); KV caches are (B, S_max, H_kv, Dh) per layer.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import (MODEL_AXIS, batch_spec, constrain,
                                 dense_init, norm_init, apply_norm)
from repro.models.layers import head_axis as L_head_axis
from repro.models.rope import apply_mrope, apply_rope

NEG_INF = -1e30


# --------------------------------------------------------------------------
# blocked online-softmax attention core
# --------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool, window: int = 0,
                    q_offset=0, kv_valid_len=None,
                    q_block: int = 1024, kv_block: int = 1024):
    """Blocked attention with online softmax (grouped-query aware).

    q: (B, Sq, Hq, Dq); k: (B, Sk, Hkv, Dq); v: (B, Sk, Hkv, Dv);
    Hq must be a multiple of Hkv.  ``q_offset`` is the absolute position of
    q[0] (scalar or traced), for causal/window masks in decode and chunked
    prefill.  ``kv_valid_len``: mask out k positions >= this (decode caches).

    Returns (B, Sq, Hq, Dv) in q.dtype.
    """
    b, sq, hq, dq = q.shape
    _, sk, hkv, _ = k.shape
    dv = v.shape[-1]
    g = hq // hkv
    scale = dq ** -0.5

    q_block = min(q_block, sq)
    kv_block = min(kv_block, sk)
    # pad to block multiples
    sq_p = -(-sq // q_block) * q_block
    sk_p = -(-sk // kv_block) * kv_block
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    if sk_p != sk:
        k = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    if kv_valid_len is None:
        kv_valid_len = sk
    nq, nk = sq_p // q_block, sk_p // kv_block

    # (B, S, H, D) -> (nq, B, Hkv, G, q_block, D)
    qb = q.reshape(b, nq, q_block, hkv, g, dq).transpose(1, 0, 3, 4, 2, 5)
    kb = k.reshape(b, nk, kv_block, hkv, dq).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, nk, kv_block, hkv, dv).transpose(1, 0, 3, 2, 4)
    # UNEVEN head sharding over the model axis (GSPMD pads the ragged
    # shard): when q-heads can't shard evenly (phi3 40H, whisper 20H,
    # minicpm3 40H, qwen2 14H over TP=16), sharding hkv raggedly beats
    # replicating the whole attention computation on every model rank
    # (12x memory on minicpm3-4b x prefill_32k; EXPERIMENTS.md §Perf
    # bonus).  hkv is a whole dim of every block tensor, so no reshape
    # ever splits it.  Archs with even q-head TP keep their layout.
    if hkv > 1 and L_head_axis(hq) is None:
        qb = constrain(qb, None, batch_spec(), MODEL_AXIS, None, None, None)
        kb = constrain(kb, None, batch_spec(), MODEL_AXIS, None, None)
        vb = constrain(vb, None, batch_spec(), MODEL_AXIS, None, None)

    def per_q_block(args):
        qi, q_idx = args                       # (B,Hkv,G,Bq,Dq), scalar
        q_pos = q_offset + q_idx * q_block + jnp.arange(q_block)

        @functools.partial(jax.checkpoint, prevent_cse=False)
        def kv_step(carry, args2):
            # checkpointed: backward recomputes s/p per block instead of
            # saving the (B,Hkv,G,Bq,Bk) probabilities — this is what makes
            # the pure-JAX flash actually O(S) memory under autodiff.
            m, lse, acc = carry
            ki, vi, k_idx = args2              # (B,Hkv,Bk,Dq), (B,Hkv,Bk,Dv)
            k_pos = k_idx * kv_block + jnp.arange(kv_block)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qi.astype(jnp.float32),
                           ki.astype(jnp.float32)) * scale
            mask = k_pos[None, :] < kv_valid_len
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            new_m = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - new_m[..., None])
            # fully-masked blocks: s == new_m == NEG_INF -> exp(0); zero them
            p = p * mask[None, None, None]
            corr = jnp.exp(m - new_m)
            lse2 = lse * corr + jnp.sum(p, axis=-1)
            acc2 = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vi.astype(jnp.float32))
            return (new_m, lse2, acc2), None

        m0 = jnp.full((b, hkv, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_block, dv), jnp.float32)
        (m, lse, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (kb, vb, jnp.arange(nk)))
        return acc / jnp.maximum(lse, 1e-30)[..., None]

    out = jax.lax.map(per_q_block, (qb, jnp.arange(nq)))
    # (nq, B, Hkv, G, Bq, Dv) -> (B, Sq, Hq, Dv)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq_p, hq, dv)
    return out[:, :sq].astype(q.dtype)


# --------------------------------------------------------------------------
# GQA attention (covers MHA, MQA, local-window, M-RoPE, cross-attn)
# --------------------------------------------------------------------------

def gqa_init(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {"w_q": dense_init(ks[0], d, nq * hd, dtype),
         "w_k": dense_init(ks[1], d, nkv * hd, dtype),
         "w_v": dense_init(ks[2], d, nkv * hd, dtype),
         "w_o": dense_init(ks[3], nq * hd, d, dtype, scale=(nq * hd) ** -0.5)}
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((nq * hd,), dtype)
        p["b_k"] = jnp.zeros((nkv * hd,), dtype)
        p["b_v"] = jnp.zeros((nkv * hd,), dtype)
    return p


class KVCache(NamedTuple):
    k: jax.Array          # (B, S_max, H_kv, Dh)
    v: jax.Array


def gqa_attention(params, x, cfg, *, positions, mode: str,
                  cache: Optional[KVCache] = None, cache_pos=None,
                  kv_source=None, window: int = 0,
                  q_block: int = 1024, kv_block: int = 1024):
    """GQA attention for train/prefill/decode (+cross when kv_source given).

    x: (B, S, D).  positions: (B, S) or (3, B, S) for M-RoPE.
    decode mode: S == 1, cache holds S_max slots, cache_pos is the write
    position (scalar int32).
    Returns (y, new_cache).
    """
    b, s, d = x.shape
    hd, nq, nkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    is_cross = kv_source is not None

    q = x @ params["w_q"]
    if "b_q" in params:
        q = q + params["b_q"]
    q = q.reshape(b, s, nq, hd)

    kv_in = kv_source if is_cross else x
    k = kv_in @ params["w_k"]
    v = kv_in @ params["w_v"]
    if "b_k" in params:
        k, v = k + params["b_k"], v + params["b_v"]
    k = k.reshape(b, kv_in.shape[1], nkv, hd)
    v = v.reshape(b, kv_in.shape[1], nkv, hd)

    if not is_cross and cfg.pos_kind == "rope":
        if cfg.mrope_sections is not None:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if mode == "decode" and not is_cross:
        assert cache is not None
        ck = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                          (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                          (0, cache_pos, 0, 0))
        new_cache = KVCache(ck, cv)
        k, v = ck, cv
        q_offset = cache_pos
        kv_valid = cache_pos + 1
        causal = False  # enforced via kv_valid
    elif mode == "decode" and is_cross:
        # cross-attn decode: reuse precomputed encoder KV from the cache
        assert cache is not None
        k, v = cache.k, cache.v
        new_cache = cache
        q_offset, kv_valid, causal = 0, None, False
    else:
        q_offset = 0
        kv_valid = None
        causal = (mode != "encode") and not is_cross
        if mode == "prefill" and not is_cross:
            new_cache = KVCache(k, v)
        elif is_cross:
            new_cache = KVCache(k, v)

    hax = L_head_axis(nq)
    q = constrain(q, batch_spec(), None, hax, None)
    if hax is not None:
        kvax = L_head_axis(nkv) if not is_cross else None
        k = constrain(k, batch_spec(), None, kvax, None)
        v = constrain(v, batch_spec(), None, kvax, None)
    y = flash_attention(q, k, v, causal=causal,
                        window=window if not is_cross else 0,
                        q_offset=q_offset, kv_valid_len=kv_valid,
                        q_block=q_block, kv_block=kv_block)
    y = y.reshape(b, s, nq * hd)
    return y @ params["w_o"], new_cache


# --------------------------------------------------------------------------
# MLA — multi-head latent attention (MiniCPM3 / DeepSeek-V2)
# --------------------------------------------------------------------------

def mla_init(key, cfg, dtype):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 7)
    return {
        "w_dq": dense_init(ks[0], d, m.q_lora_rank, dtype),
        "q_norm": norm_init(m.q_lora_rank, "rms", dtype),
        "w_uq": dense_init(ks[1], m.q_lora_rank,
                           h * (m.qk_nope_dim + m.qk_rope_dim), dtype),
        "w_dkv": dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_dim, dtype),
        "kv_norm": norm_init(m.kv_lora_rank, "rms", dtype),
        "w_uk": dense_init(ks[3], m.kv_lora_rank, h * m.qk_nope_dim, dtype),
        "w_uv": dense_init(ks[4], m.kv_lora_rank, h * m.v_head_dim, dtype),
        "w_o": dense_init(ks[5], h * m.v_head_dim, d, dtype,
                          scale=(h * m.v_head_dim) ** -0.5),
    }


class MLACache(NamedTuple):
    c_kv: jax.Array       # (B, S_max, kv_lora_rank)
    k_rope: jax.Array     # (B, S_max, qk_rope_dim)


def mla_attention(params, x, cfg, *, positions, mode: str,
                  cache: Optional[MLACache] = None, cache_pos=None,
                  q_block: int = 1024, kv_block: int = 1024):
    """MLA: latent-compressed KV.  Decode uses the absorbed-matmul form so
    the cache stays (kv_lora + rope) wide — the technique's memory win."""
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    dn, dr, dv = m.qk_nope_dim, m.qk_rope_dim, m.v_head_dim

    cq = apply_norm(params["q_norm"], x @ params["w_dq"], "rms")
    q = (cq @ params["w_uq"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]

    dkv = x @ params["w_dkv"]                                 # (B,S,rank+dr)
    c_kv = apply_norm(params["kv_norm"], dkv[..., :m.kv_lora_rank], "rms")
    k_rope = dkv[..., m.kv_lora_rank:]                        # (B,S,dr)

    if mode == "decode":
        assert cache is not None
        q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
        k_rope = apply_rope(k_rope[:, :, None, :], positions,
                            cfg.rope_theta)[:, :, 0]
        cc = _masked_cache_write(cache.c_kv, c_kv, cache_pos)
        cr = _masked_cache_write(cache.k_rope, k_rope, cache_pos)
        new_cache = MLACache(cc, cr)
        s_max = cc.shape[1]
        # absorbed: q_abs[b,1,h,r] = q_nope . W_uk(r, h, dn)
        w_uk = params["w_uk"].reshape(m.kv_lora_rank, h, dn)
        q_abs = jnp.einsum("bshd,rhd->bshr", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))
        scores = jnp.einsum("bshr,btr->bhst", q_abs,
                            cc.astype(jnp.float32))
        scores += jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32),
                             cr.astype(jnp.float32))
        scores *= (dn + dr) ** -0.5
        valid = jnp.arange(s_max)[None, None, None] <= cache_pos
        scores = jnp.where(valid, scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhst,btr->bshr", p, cc.astype(jnp.float32))
        w_uv = params["w_uv"].reshape(m.kv_lora_rank, h, dv)
        y = jnp.einsum("bshr,rhd->bshd", ctx, w_uv.astype(jnp.float32))
        y = y.reshape(b, s, h * dv).astype(x.dtype)
        return y @ params["w_o"], new_cache

    # train / prefill: expand to standard multi-head form
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    k_rope_r = apply_rope(k_rope[:, :, None, :], positions,
                          cfg.rope_theta)                     # (B,S,1,dr)
    k_nope = (c_kv @ params["w_uk"]).reshape(b, s, h, dn)
    v = (c_kv @ params["w_uv"]).reshape(b, s, h, dv)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope_r, (b, s, h, dr))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    new_cache = MLACache(c_kv, k_rope_r[:, :, 0]) if mode == "prefill" else None
    y = flash_attention(q_full, k, v, causal=True,
                        q_block=q_block, kv_block=kv_block)
    y = y.reshape(b, s, h * dv)
    return y @ params["w_o"], new_cache


# --------------------------------------------------------------------------
# decode paths (Sq == 1): plain masked attention over the cache
# --------------------------------------------------------------------------

class WindowKVCache(NamedTuple):
    """Ring-buffer KV cache for sliding-window attention (O(window) memory,
    the reason hybrid archs can run long_500k).  pos_slots stores absolute
    positions per slot (-1 = empty)."""
    k: jax.Array            # (B, W, H_kv, Dh)
    v: jax.Array
    pos_slots: jax.Array    # (W,) int32


def _plain_decode_attn(q, k, v, mask):
    """q: (B,1,Hq,D); k/v: (B,S,Hkv,D); mask: (B,1,1,S) or (1,1,1,S).

    Operands stay in the cache dtype with f32 ACCUMULATION
    (preferred_element_type) — casting the cache to f32 makes XLA hoist a
    float32 copy of the entire stacked cache out of the layer scan.
    """
    b, _, hq, dq = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, 1, hkv, g, dq).astype(k.dtype)
    s = jnp.einsum("bqhgd,bshd->bhgqs", qg, k,
                   preferred_element_type=jnp.float32) * dq ** -0.5
    s = jnp.where(mask[:, :, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqs,bshd->bqhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, hq, -1).astype(q.dtype)


def _masked_cache_write(cache_arr, new, cache_pos, seq_axis=1):
    """Write ``new`` (length-1 seq) at ``cache_pos`` WITHOUT
    dynamic-update-slice: a select against iota stays elementwise over a
    sequence-SHARDED cache dim, while DUS with a traced index forces the
    SPMD partitioner to re-materialize the whole cache per layer."""
    s_max = cache_arr.shape[seq_axis]
    iota_shape = [1] * cache_arr.ndim
    iota_shape[seq_axis] = s_max
    sel = (jax.lax.broadcasted_iota(jnp.int32, tuple(iota_shape), seq_axis)
           == cache_pos)
    return jnp.where(sel, new.astype(cache_arr.dtype), cache_arr)


def gqa_decode(params, x, cfg, *, cache, cache_pos, positions):
    """Single-token decode against a full-length cache."""
    b, s, d = x.shape
    hd, nq, nkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    q = x @ params["w_q"]
    k = x @ params["w_k"]
    v = x @ params["w_v"]
    if "b_q" in params:
        q, k, v = q + params["b_q"], k + params["b_k"], v + params["b_v"]
    q = q.reshape(b, 1, nq, hd)
    k = k.reshape(b, 1, nkv, hd)
    v = v.reshape(b, 1, nkv, hd)
    if cfg.pos_kind == "rope":
        if cfg.mrope_sections is not None:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)

    ck = _masked_cache_write(cache.k, k, cache_pos)
    cv = _masked_cache_write(cache.v, v, cache_pos)
    s_max = ck.shape[1]
    mask = (jnp.arange(s_max) <= cache_pos)[None, None, None]
    y = _plain_decode_attn(q, ck, cv, mask)
    y = y.reshape(b, 1, nq * hd)
    return y @ params["w_o"], KVCache(ck, cv)


def gqa_decode_window(params, x, cfg, *, cache: WindowKVCache, cache_pos,
                      positions):
    """Single-token decode against a ring-buffer window cache."""
    b, s, d = x.shape
    hd, nq, nkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    w = cache.k.shape[1]
    q = x @ params["w_q"]
    k = x @ params["w_k"]
    v = x @ params["w_v"]
    if "b_q" in params:
        q, k, v = q + params["b_q"], k + params["b_k"], v + params["b_v"]
    q = q.reshape(b, 1, nq, hd)
    k = k.reshape(b, 1, nkv, hd)
    v = v.reshape(b, 1, nkv, hd)
    if cfg.pos_kind == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    slot = cache_pos % w
    ck = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                      (0, slot, 0, 0))
    pos_slots = jax.lax.dynamic_update_slice(
        cache.pos_slots, cache_pos[None].astype(jnp.int32), (slot,))
    # valid: written, within window of the current position
    valid = (pos_slots >= 0) & (pos_slots <= cache_pos) \
        & (cache_pos - pos_slots < w)
    mask = valid[None, None, None]
    y = _plain_decode_attn(q, ck, cv, mask)
    y = y.reshape(b, 1, nq * hd)
    return y @ params["w_o"], WindowKVCache(ck, cv, pos_slots)


def cross_decode(params, x, cfg, *, cache: KVCache):
    """Cross-attention decode: static encoder KV, no masking."""
    b = x.shape[0]
    hd, nq = cfg.resolved_head_dim, cfg.n_heads
    q = x @ params["w_q"]
    if "b_q" in params:
        q = q + params["b_q"]
    q = q.reshape(b, 1, nq, hd)
    s_enc = cache.k.shape[1]
    mask = jnp.ones((1, 1, 1, s_enc), bool)
    y = _plain_decode_attn(q, cache.k, cache.v, mask)
    y = y.reshape(b, 1, nq * hd)
    return y @ params["w_o"], cache
