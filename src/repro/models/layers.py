"""Basic layers: norms, dense, embeddings, FFNs.

Parameters are plain pytrees (nested dicts of jnp arrays).  Activation
sharding is annotated with ``constrain`` which is a no-op outside a mesh
context, so the same code runs in CPU smoke tests and the 512-device
dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import jaxcompat

# mesh axis-name conventions used everywhere
BATCH_AXES = ("pod", "data")   # "pod" present only in the multi-pod mesh
MODEL_AXIS = "model"


def _mesh_axis_names(auto_only: bool = False):
    return jaxcompat.mesh_axis_names(auto_only=auto_only)


def constrain(x, *spec):
    """with_sharding_constraint with graceful no-op off-mesh.

    spec entries are axis names, tuples of axis names, or None; axis names
    not present in the current mesh (or manual in the current shard_map
    region) are dropped.
    """
    names = _mesh_axis_names(auto_only=True)
    if not names:
        return x

    def fix(s):
        if s is None:
            return None
        if isinstance(s, (tuple, list)):
            kept = tuple(a for a in s if a in names)
            return kept if kept else None
        return s if s in names else None

    return jax.lax.with_sharding_constraint(x, P(*(fix(s) for s in spec)))


def batch_spec():
    """The (possibly multi-pod) batch sharding axes present in the mesh."""
    names = _mesh_axis_names()
    kept = tuple(a for a in BATCH_AXES if a in names)
    return kept if kept else None


def model_size() -> int:
    """Size of the model axis in the current (abstract) mesh, else 1."""
    shape = jaxcompat.mesh_shape()
    return shape.get(MODEL_AXIS, 1)


def head_axis(n_heads: int):
    """``model`` iff the head count divides the model axis evenly."""
    ms = model_size()
    return MODEL_AXIS if ms > 1 and n_heads % ms == 0 else None


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def norm_init(d: int, kind: str, dtype):
    if kind == "rms":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_norm(params, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rms":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# dense FFN (SwiGLU / GELU)
# --------------------------------------------------------------------------

def ffn_init(key, d: int, d_ff: int, act: str, dtype):
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        return {"w_gate": dense_init(ks[0], d, d_ff, dtype),
                "w_up": dense_init(ks[1], d, d_ff, dtype),
                "w_down": dense_init(ks[2], d_ff, d, dtype, scale=d_ff ** -0.5)}
    return {"w_up": dense_init(ks[0], d, d_ff, dtype),
            "b_up": jnp.zeros((d_ff,), dtype),
            "w_down": dense_init(ks[1], d_ff, d, dtype, scale=d_ff ** -0.5),
            "b_down": jnp.zeros((d,), dtype)}


def apply_ffn(params, x, act: str):
    if act == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
        h = constrain(h, batch_spec(), None, MODEL_AXIS)
        return h @ params["w_down"]
    h = jax.nn.gelu(x @ params["w_up"] + params["b_up"])
    h = constrain(h, batch_spec(), None, MODEL_AXIS)
    return h @ params["w_down"] + params["b_down"]


# --------------------------------------------------------------------------
# RWKV channel mix (the rwkv_channel_mix "ffn")
# --------------------------------------------------------------------------

def rwkv_cmix_init(key, d: int, d_ff: int, dtype):
    ks = jax.random.split(key, 4)
    return {"w_k": dense_init(ks[0], d, d_ff, dtype),
            "w_v": dense_init(ks[1], d_ff, d, dtype, scale=d_ff ** -0.5),
            "w_r": dense_init(ks[2], d, d, dtype),
            "mix_k": jnp.full((d,), 0.5, dtype),
            "mix_r": jnp.full((d,), 0.5, dtype)}


def apply_rwkv_cmix(params, x, x_prev):
    """RWKV channel mix with token shift.  x: (B,S,D); x_prev: (B,1,D) f32
    carry (returned as f32 so decode cache dtypes are stable)."""
    shifted = jnp.concatenate([x_prev.astype(x.dtype), x[:, :-1]], axis=1)
    xk = x * params["mix_k"] + shifted * (1 - params["mix_k"])
    xr = x * params["mix_r"] + shifted * (1 - params["mix_r"])
    k = jnp.square(jax.nn.relu(xk @ params["w_k"]))
    k = constrain(k, batch_spec(), None, MODEL_AXIS)
    v = k @ params["w_v"]
    r = jax.nn.sigmoid(xr @ params["w_r"])
    return r * v, x[:, -1:].astype(jnp.float32)
