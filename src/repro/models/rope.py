"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, Dh); positions: (B, S) int32."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                       # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs       # (B,S,half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections: tuple):
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, Dh); positions3: (3, B, S) (t, h, w) position streams;
    sections: per-stream frequency-section sizes summing to Dh // 2.
    For text tokens all three streams are equal and M-RoPE == RoPE.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)                        # (half,)
    # pick which position stream drives each frequency section
    sec_id = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                        total_repeat_length=half)                 # (half,)
    pos = positions3.astype(jnp.float32)                          # (3,B,S)
    ang = jnp.take(pos, sec_id, axis=0)                           # (half,B,S)
    ang = jnp.moveaxis(ang, 0, -1) * freqs                        # (B,S,half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


def sinusoidal_embedding(seq_len: int, d: int, dtype):
    """Whisper-style sinusoidal position table (computed, not learned)."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / max(half - 1, 1))
    ang = pos * freqs[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)
