"""Griffin / RecurrentGemma recurrent block: temporal conv + RG-LRU
(arXiv:2402.19427), evaluated with an associative scan (TPU-parallel).

RG-LRU:  a_t = exp(-c * softplus(Λ) * sigmoid(W_a x_t)),
         h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

_C = 8.0  # griffin's fixed recurrence sharpness constant
_N_BLOCKS = 16  # block-diagonal gate matrices


def griffin_init(key, cfg, dtype):
    d = cfg.d_model
    lw = cfg.recurrent.lru_width or d
    cw = cfg.recurrent.conv_width
    nb = _N_BLOCKS if lw % _N_BLOCKS == 0 else 1
    bs = lw // nb
    ks = jax.random.split(key, 6)
    # Λ init so that a^c = exp(-c softplus Λ) spans ~[0.9, 0.999]
    lam = jnp.log(jnp.expm1(
        -jnp.log(jnp.linspace(0.9, 0.999, lw)) / _C)).astype(jnp.float32)
    return {
        "w_x": dense_init(ks[0], d, lw, dtype),
        "w_gate": dense_init(ks[1], d, lw, dtype),
        "conv_w": (jax.random.normal(ks[2], (cw, lw), jnp.float32)
                   * cw ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((lw,), dtype),
        "w_a": (jax.random.normal(ks[3], (nb, bs, bs), jnp.float32)
                * bs ** -0.5).astype(dtype),
        "w_i": (jax.random.normal(ks[4], (nb, bs, bs), jnp.float32)
                * bs ** -0.5).astype(dtype),
        "lam": lam,
        "w_out": dense_init(ks[5], lw, d, dtype, scale=lw ** -0.5),
    }


def _block_diag(x, w):
    """x: (B,S,L) @ block-diagonal w: (nb, bs, bs) -> (B,S,L)."""
    b, s, d = x.shape
    nb = w.shape[0]
    xr = x.reshape(b, s, nb, d // nb)
    return jnp.einsum("bsnl,nlm->bsnm", xr, w).reshape(b, s, d)


def rglru(x, a_gate, i_gate, lam, h0):
    """x, gates: (B,S,L); lam: (L,); h0: (B,L) f32. Returns (h (B,S,L), h_S)."""
    f32 = jnp.float32
    r = jax.nn.sigmoid(a_gate.astype(f32))
    i = jax.nn.sigmoid(i_gate.astype(f32))
    log_a = -_C * jax.nn.softplus(lam)[None, None] * r          # <= 0
    a = jnp.exp(log_a)
    gated = x.astype(f32) * i * jnp.sqrt(
        jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))

    if x.shape[1] == 1:  # decode
        h = a[:, 0] * h0 + gated[:, 0]
        return h[:, None].astype(x.dtype), h

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, b1 * a2 + b2

    a_cum, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    h = h + a_cum * h0[:, None]
    return h.astype(x.dtype), h[:, -1]


def apply_griffin(params, x, cfg, *, state):
    """Griffin recurrent block.  x: (B,S,D);
    state: (h (B,L) f32, conv_buf (B, cw-1, L)).  Returns (y, state')."""
    r = cfg.recurrent
    cw = r.conv_width
    h0, conv_buf = state

    xb = x @ params["w_x"]                                     # (B,S,L)
    gb = jax.nn.gelu(x @ params["w_gate"])

    # causal depthwise temporal conv of width cw with carried buffer
    padded = jnp.concatenate([conv_buf.astype(xb.dtype), xb], axis=1)
    conv = sum(padded[:, j:j + xb.shape[1]] * params["conv_w"][j]
               for j in range(cw)) + params["conv_b"]
    new_buf = padded[:, -(cw - 1):].astype(jnp.float32) if cw > 1 else conv_buf

    a_gate = _block_diag(conv, params["w_a"])
    i_gate = _block_diag(conv, params["w_i"])
    h, h_last = rglru(conv, a_gate, i_gate, params["lam"], h0)

    y = (h * gb) @ params["w_out"]
    return y, (h_last, new_buf)


def griffin_init_state(cfg, batch: int):
    r = cfg.recurrent
    lw = r.lru_width or cfg.d_model
    return (jnp.zeros((batch, lw), jnp.float32),
            jnp.zeros((batch, r.conv_width - 1, lw), jnp.float32))
