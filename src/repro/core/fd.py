"""FD — fully-distributed top-k over a sharded score axis.

The paper's four phases, mapped to a TPU mesh axis (devices = peers):

  1. query forward     — implicit: the jitted program *is* the query; every
                         device already holds it (compile-time flooding,
                         each "edge" used zero times at runtime — stronger
                         than Strategy 1+2's once-per-edge).
  2. local execution   — ``local_topk`` over the device's score shard
                         (Pallas kernel on TPU).
  3. merge-and-backward— log2(n) ppermute rounds merging (score, index)
                         k-lists along a halving tree (device 0 =
                         query originator), doubling butterfly, or ring.
  4. data retrieval    — fetch only the k winning rows from their owners
                         (masked psum — at most k items cross the network,
                         the paper's m_rt <= 2k).

Baselines (paper §5.1):
  * CN  — every peer ships its *full* local data to the originator
          (all-gather of the raw scores).
  * CN* — every peer ships only its local k-list to the originator
          (all-gather of k-lists, merge at the root).

All functions with the ``_shard`` suffix must be called inside
``jax.shard_map``; the plain versions wrap them given a mesh + axis name.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import jaxcompat
from repro.core import topology
from repro.kernels.merge import merge_scorelists
from repro.kernels.topk import local_topk


# --------------------------------------------------------------------------
# In-shard_map collective top-k
# --------------------------------------------------------------------------

def fd_topk_shard(local_scores: jax.Array, k: int, axis_name: str,
                  axis_size: int, *, schedule: str = "halving",
                  use_pallas: bool = False) -> tuple:
    """Global top-k of a score axis sharded over ``axis_name``.

    local_scores: (..., n_local) on each device; global index of local
    column j is ``axis_index * n_local + j``.

    Returns (vals, idx): (..., k), identical on every device.
    """
    n_local = local_scores.shape[-1]
    ax = jax.lax.axis_index(axis_name)

    # Phase 2: local query execution.
    vals, idx = local_topk(local_scores, k, use_pallas=use_pallas)
    idx = idx + (ax * n_local).astype(jnp.int32)

    # Phase 3: merge-and-backward.
    if schedule == "doubling":
        for perm in topology.doubling_rounds(axis_size):
            pv = jax.lax.ppermute(vals, axis_name, perm)
            pi = jax.lax.ppermute(idx, axis_name, perm)
            vals, idx = merge_scorelists(vals, idx, pv, pi)
        return vals, idx

    if schedule == "halving":
        for perm, receivers in topology.halving_rounds(axis_size):
            pv = jax.lax.ppermute(vals, axis_name, perm)
            pi = jax.lax.ppermute(idx, axis_name, perm)
            # non-receivers got zeros; mask them to -inf so merge is a no-op
            recv = jnp.isin(ax, jnp.asarray(sorted(receivers)))
            pv = jnp.where(recv, pv, -jnp.inf)
            pi = jnp.where(recv, pi, -1)
            vals, idx = merge_scorelists(vals, idx, pv, pi)
        # device 0 (query originator) now holds the final score-list;
        # broadcast it (the retrieval-phase "ask" fan-out).
        vals = jax.lax.psum(jnp.where(ax == 0, vals, 0.0), axis_name)
        idx = jax.lax.psum(jnp.where(ax == 0, idx, 0), axis_name)
        return vals, idx

    if schedule == "ring":
        # relay each peer's ORIGINAL k-list around the ring; merging the
        # accumulator would re-introduce duplicates of already-seen lists.
        relay_v, relay_i = vals, idx
        for perm in topology.ring_rounds(axis_size):
            relay_v = jax.lax.ppermute(relay_v, axis_name, perm)
            relay_i = jax.lax.ppermute(relay_i, axis_name, perm)
            vals, idx = merge_scorelists(vals, idx, relay_v, relay_i)
        return vals, idx

    raise ValueError(f"unknown schedule {schedule!r}")


def cn_topk_shard(local_scores: jax.Array, k: int, axis_name: str) -> tuple:
    """CN baseline: all-gather the full scores, top-k locally."""
    full = jax.lax.all_gather(local_scores, axis_name, axis=-1, tiled=True)
    return local_topk(full, k)


def cn_star_topk_shard(local_scores: jax.Array, k: int, axis_name: str,
                       axis_size: int) -> tuple:
    """CN* baseline: all-gather only the k-lists, merge locally."""
    n_local = local_scores.shape[-1]
    ax = jax.lax.axis_index(axis_name)
    vals, idx = local_topk(local_scores, k)
    idx = idx + (ax * n_local).astype(jnp.int32)
    all_v = jax.lax.all_gather(vals, axis_name, axis=-1, tiled=True)  # (...,k*n)
    all_i = jax.lax.all_gather(idx, axis_name, axis=-1, tiled=True)
    mv, pos = jax.lax.top_k(all_v, k)
    mi = jnp.take_along_axis(all_i, pos, axis=-1)
    return mv, mi


def fd_topk_gather_shard(local_scores: jax.Array, local_rows: jax.Array,
                         k: int, axis_name: str, axis_size: int, *,
                         schedule: str = "halving") -> tuple:
    """Phases 2-4 over a sharded table: return the k winning *rows*.

    local_scores: (..., n_local) — leading dims are a query batch over the
    same table; local_rows: (n_local, d).  Only k rows per query cross
    the network (phase 4 = masked psum), vs CN's n_local * n rows.
    Returns (vals (..., k), idx (..., k), rows (..., k, d)).
    """
    n_local = local_scores.shape[-1]
    ax = jax.lax.axis_index(axis_name)
    vals, idx = fd_topk_shard(local_scores, k, axis_name, axis_size,
                              schedule=schedule)
    # Phase 4: data retrieval — each winner row is contributed by its owner.
    owner = idx // n_local
    local_pos = jnp.clip(idx - ax * n_local, 0, n_local - 1)
    rows = jnp.take(local_rows, local_pos, axis=0)          # (..., k, d)
    mask = (owner == ax)[..., None].astype(local_rows.dtype)
    rows = jax.lax.psum(rows * mask, axis_name)
    return vals, idx, rows


# --------------------------------------------------------------------------
# Mesh-level wrappers
# --------------------------------------------------------------------------

def _batch_lead_spec(scores: jax.Array, mesh, batch_axes) -> list:
    """Leading-dim spec entries for a batched query axis.

    The first (batch) dim is sharded over the ``batch_axes`` present in
    the mesh when its size divides their product; otherwise the batch is
    replicated and only the score axis is sharded.
    """
    lead = [None] * (scores.ndim - 1)
    if batch_axes and scores.ndim > 1:
        present = tuple(a for a in batch_axes if a in mesh.axis_names)
        if present and scores.shape[0] % math.prod(
                dict(mesh.shape)[a] for a in present) == 0:
            lead[0] = present
    return lead


def fd_topk(scores: jax.Array, k: int, mesh, axis: str = "model", *,
            schedule: str = "halving", algorithm: str = "fd",
            use_pallas: bool = False, batch_axes=None) -> tuple:
    """Global top-k of ``scores`` (..., N) sharded over mesh axis ``axis``.

    algorithm: "fd" | "cn" | "cn_star".
    ``batch_axes``: mesh axes the leading (batch) dim is sharded over —
    collectives then run only over ``axis`` within each batch shard.
    Returns (vals, idx) of shape (..., k), replicated over ``axis``.
    """
    n = scores.shape[-1]
    axis_size = dict(mesh.shape)[axis]
    if n % axis_size:
        raise ValueError(f"score dim {n} not divisible by axis {axis_size}")
    lead = _batch_lead_spec(scores, mesh, batch_axes)
    in_spec = P(*(lead + [axis]))
    out_spec = P(*(lead + [None]))

    def fn(local):
        if algorithm == "fd":
            return fd_topk_shard(local, k, axis, axis_size,
                                 schedule=schedule, use_pallas=use_pallas)
        if algorithm == "cn":
            return cn_topk_shard(local, k, axis)
        if algorithm == "cn_star":
            return cn_star_topk_shard(local, k, axis, axis_size)
        raise ValueError(algorithm)

    return jaxcompat.shard_map(fn, mesh=mesh, in_specs=(in_spec,),
                               out_specs=(out_spec, out_spec))(scores)


def fd_topk_gather(scores: jax.Array, rows: jax.Array, k: int, mesh,
                   axis: str = "model", *, schedule: str = "halving",
                   batch_axes=None) -> tuple:
    """Top-k rows of a sharded (N, d) table by sharded scores.

    scores: (..., N) — a leading batch of queries over the SAME table is
    supported and, with ``batch_axes``, sharded over those mesh axes
    (phase 4's masked psum then moves k rows per query per batch shard).
    rows: (N, d), sharded over ``axis`` only.
    Returns (vals (..., k), idx (..., k), rows (..., k, d)).
    """
    axis_size = dict(mesh.shape)[axis]
    lead = _batch_lead_spec(scores, mesh, batch_axes)
    in_spec = P(*(lead + [axis]))
    out_spec = P(*(lead + [None]))
    return jaxcompat.shard_map(
        functools.partial(fd_topk_gather_shard, k=k, axis_name=axis,
                          axis_size=axis_size, schedule=schedule),
        mesh=mesh,
        in_specs=(in_spec, P(axis, None)),
        out_specs=(out_spec, out_spec, P(*(lead + [None, None]))))(
            scores, rows)


# --------------------------------------------------------------------------
# Communication model (for EXPERIMENTS.md tables; matches paper §3.2)
# --------------------------------------------------------------------------

def comm_bytes(algorithm: str, n_dev: int, n_local: int, k: int,
               schedule: str = "halving", elem_bytes: int = 4) -> int:
    """Total bytes crossing links for one top-k query over n_dev shards."""
    if algorithm == "cn":
        return topology.allgather_bytes(n_dev, n_local, elem_bytes)
    if algorithm == "cn_star":
        return topology.allgather_bytes(n_dev, k, 8)
    if algorithm == "fd":
        merge = topology.schedule_list_bytes(schedule, n_dev, k)
        bcast = k * 8 * (n_dev - 1) if schedule == "halving" else 0
        return merge + bcast
    raise ValueError(algorithm)
