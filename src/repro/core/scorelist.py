"""Score-lists: the paper's unit of communication.

A score-list is a fixed-size list of k (score, address) couples, descending
by score.  On TPU: (f32 values, i32 global indices) arrays whose last axis
is k.  ``ENTRY_BYTES`` mirrors the paper's L=10 analysis (we use 4+4).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.merge import merge_ref, merge_scorelists  # noqa: F401
from repro.kernels.topk import local_topk  # noqa: F401

ENTRY_BYTES = 8  # f32 score + i32 global index (paper: 4 B score + 6 B addr)


def empty_scorelist(shape_prefix: tuple, k: int):
    """An all-(-inf) score-list — the identity element of merge."""
    vals = jnp.full(shape_prefix + (k,), -jnp.inf, jnp.float32)
    idx = jnp.full(shape_prefix + (k,), -1, jnp.int32)
    return vals, idx


def scorelist_bytes(k: int, n_lists: int = 1) -> int:
    """b = k * L * n  (paper §3.2: b_bw = k*L*(|P_Q|-1))."""
    return k * ENTRY_BYTES * n_lists
