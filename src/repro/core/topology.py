"""Collective schedules: the TPU-native analogue of the paper's overlay.

The paper bubbles score-lists up a spanning tree of the (unstructured)
overlay; Strategies 1+2 ensure each edge carries the query once.  On a TPU
mesh we can pick the tree at compile time.  Three schedules are provided:

  * ``halving``   — recursive halving: the paper's merge-and-backward, with
                    device 0 as the query originator.  log2(n) rounds; a
                    link is used at most once per round and the total number
                    of list transfers is n-1 — the paper's Lemma 2 lower
                    bound (one message per non-originator peer).
  * ``doubling``  — recursive doubling (butterfly): every device ends with
                    the global top-k (no broadcast needed); n*log2(n)
                    transfers.
  * ``ring``      — n-1 rounds around a ring; n*(n-1) transfers but only
                    nearest-neighbour links (torus-friendly).

Each round is a `jax.lax.ppermute` permutation; `*_rounds(n)` return the
(src, dst) pair lists plus a per-device activity mask for merging.
"""
from __future__ import annotations

import math

from repro.core.scorelist import ENTRY_BYTES

SCHEDULES = ("halving", "doubling", "ring")


def _log2(n: int) -> int:
    e = int(math.log2(n))
    if 2 ** e != n:
        raise ValueError(f"axis size {n} must be a power of two")
    return e


def doubling_rounds(n: int):
    """[(perm, None)] — every device both sends and merges each round."""
    return [[(i, i ^ (1 << r)) for i in range(n)] for r in range(_log2(n))]


def halving_rounds(n: int):
    """[(perm, receiver_set)] — bubble-up to originator (device 0).

    Round r: devices with idx % 2^(r+1) == 2^r send their list to
    idx - 2^r; only receivers merge.
    """
    rounds = []
    for r in range(_log2(n)):
        step = 1 << r
        senders = [i for i in range(n) if i % (2 * step) == step]
        perm = [(i, i - step) for i in senders]
        receivers = {i - step for i in senders}
        rounds.append((perm, receivers))
    return rounds


def ring_rounds(n: int):
    return [[(i, (i + 1) % n) for i in range(n)] for _ in range(n - 1)]


def schedule_transfers(schedule: str, n: int) -> int:
    """Number of k-list point-to-point transfers (paper's m_bw analogue)."""
    if schedule == "halving":
        return n - 1                      # == Lemma 2 lower bound
    if schedule == "doubling":
        return n * _log2(n)
    if schedule == "ring":
        return n * (n - 1)
    raise ValueError(schedule)


def schedule_list_bytes(schedule: str, n: int, k: int,
                        entry_bytes: int = ENTRY_BYTES) -> int:
    """Total bytes moved by the merge phase (all links summed)."""
    return schedule_transfers(schedule, n) * k * entry_bytes


def allgather_bytes(n: int, shard_elems: int, elem_bytes: int) -> int:
    """Total bytes for a ring all-gather of per-device shards (CN/CN*)."""
    return n * (n - 1) * shard_elems * elem_bytes


def measure_comm_bytes(algorithm: str, n_dev: int, n_local: int, k: int,
                       schedule: str = "halving",
                       elem_bytes: int = 4) -> int:
    """Bytes measured by *walking* the actual round structure.

    The closed forms in ``fd.comm_bytes`` / ``schedule_list_bytes`` are
    models; this tallies every point-to-point transfer the schedules
    actually emit — each ppermute pair moves one (score, index) k-list
    (``ENTRY_BYTES`` per couple), the halving epilogue broadcasts the
    originator's list to the other n-1 devices, and CN/CN* move their
    payload with a ring all-gather (n-1 rounds, one shard per device per
    round).  Tests assert this equals the closed-form model.
    """
    if algorithm == "cn":
        return _measure_ring_allgather(n_dev, n_local, elem_bytes)
    if algorithm == "cn_star":
        return _measure_ring_allgather(n_dev, k, ENTRY_BYTES)
    if algorithm != "fd":
        raise ValueError(algorithm)
    total = 0
    list_bytes = k * ENTRY_BYTES
    if schedule == "halving":
        for perm, _receivers in halving_rounds(n_dev):
            total += len(perm) * list_bytes
        total += (n_dev - 1) * k * ENTRY_BYTES     # originator broadcast
    elif schedule == "doubling":
        for perm in doubling_rounds(n_dev):
            total += len(perm) * list_bytes
    elif schedule == "ring":
        for perm in ring_rounds(n_dev):
            total += len(perm) * list_bytes
    else:
        raise ValueError(schedule)
    return total


def _measure_ring_allgather(n: int, shard_elems: int,
                            elem_bytes: int) -> int:
    """Ring all-gather, round by round: every device forwards one shard
    to its successor each of the n-1 rounds."""
    total = 0
    for _round in range(n - 1):
        for _dev in range(n):
            total += shard_elems * elem_bytes
    return total
