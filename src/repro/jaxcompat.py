"""Version-compat shims over JAX API drift (0.4.x ↔ ≥0.6).

The repo is written against the modern surface — ``jax.shard_map``,
``jax.sharding.AxisType`` / ``get_abstract_mesh`` / ``set_mesh`` — but
must also run on 0.4.x jaxlibs where those names do not exist.  Every
call site goes through this module instead of feature-testing inline.

Mapping (new → old):
  * ``jax.shard_map(..., axis_names=M, check_vma=False)``
      → ``jax.experimental.shard_map.shard_map(..., check_rep=False,
         auto=all_axes - M)``
  * ``jax.make_mesh(..., axis_types=(Auto,)*r)``
      → ``jax.make_mesh(...)`` (axis types predate 0.5; all axes are
         implicitly auto)
  * ``jax.sharding.set_mesh(mesh)`` → the mesh itself (old ``Mesh`` is
      its own context manager and sets ``thread_resources``)
  * ``jax.sharding.get_abstract_mesh()`` → the thread-resources
      physical mesh; manual axes are detected via the bound axis env
      (``axis_frame`` raises ``NameError`` outside shard_map).
"""
from __future__ import annotations

import contextlib

import jax
import jax.experimental  # noqa: F401  (feature-probed in enable_x64)

_NEW_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
_HAS_ABSTRACT_MESH = hasattr(jax.sharding, "get_abstract_mesh")


def axis_type_auto():
    """``AxisType.Auto`` where it exists, else None (all axes are auto)."""
    return jax.sharding.AxisType.Auto if _HAS_AXIS_TYPE else None


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with every axis auto, on old and new JAX."""
    kw = {} if devices is None else {"devices": devices}
    if _HAS_AXIS_TYPE:
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)


def shard_map(fn, *, mesh, in_specs, out_specs, axis_names=None):
    """``jax.shard_map`` with replication checks off.

    ``axis_names``: the MANUAL axes (None → all mesh axes manual), i.e.
    the new-API meaning; mapped to old-API ``auto`` as the complement.
    """
    if _NEW_SHARD_MAP:
        kw = {} if axis_names is None else {"axis_names": set(axis_names)}
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = (frozenset() if axis_names is None
            else frozenset(mesh.axis_names) - frozenset(axis_names))
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False, auto=auto)


def use_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh."""
    if hasattr(jax.sharding, "set_mesh"):
        return jax.sharding.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh                      # old JAX: Mesh is a context manager


def current_mesh():
    """The ambient (abstract) mesh, or None outside any mesh context."""
    if _HAS_ABSTRACT_MESH:
        m = jax.sharding.get_abstract_mesh()
        if m is None or not m.axis_names:
            return None
        return m
    from jax._src import mesh as mesh_lib
    m = mesh_lib.thread_resources.env.physical_mesh
    return None if m.empty else m


def _axis_is_bound(name: str) -> bool:
    """Old JAX: an axis bound in the axis env is manual (inside shard_map)."""
    from jax._src import core as jcore
    try:
        jcore.axis_frame(name)
        return True
    except Exception:
        return False


def mesh_axis_names(auto_only: bool = False) -> tuple:
    """Names of the ambient mesh axes; ``auto_only`` drops manual axes."""
    m = current_mesh()
    if m is None:
        return ()
    names = tuple(m.axis_names)
    if not auto_only:
        return names
    if _HAS_AXIS_TYPE and hasattr(m, "axis_types"):
        auto = jax.sharding.AxisType.Auto
        return tuple(n for n, t in zip(names, m.axis_types) if t == auto)
    return tuple(n for n in names if not _axis_is_bound(n))


def enable_x64():
    """Context manager scoping float64 tracing to the enclosed block.

    ``jax.experimental.enable_x64`` where it exists (the whole 0.4–0.7
    line today), else a set/restore of the global flag.  The jitted
    simulator sweeps (``repro.engine.sim_jax``) trace AND call inside
    this context so their float64 parity contract never leaks the x64
    default into the rest of the process (kernels, device tests and the
    model stack all run the JAX-default float32).
    """
    if hasattr(jax.experimental, "enable_x64"):
        return jax.experimental.enable_x64()

    @contextlib.contextmanager
    def _scoped():
        old = bool(jax.config.jax_enable_x64)
        jax.config.update("jax_enable_x64", True)
        try:
            yield
        finally:
            jax.config.update("jax_enable_x64", old)
    return _scoped()


def pallas_tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (≥0.7) / ``TPUCompilerParams`` (0.4–0.6)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


def mesh_shape() -> dict:
    """{axis: size} of the ambient mesh ({} when there is none)."""
    m = current_mesh()
    return {} if m is None else dict(m.shape)
