from repro.kernels.merge.merge import merge_pallas  # noqa: F401
from repro.kernels.merge.ops import merge_scorelists  # noqa: F401
from repro.kernels.merge.ref import merge_ref  # noqa: F401
