"""Pure-jnp oracle for the score-list merge kernel.

The paper's Merge-and-Backward phase: a peer merges the k-lists received
from its children with its own local k-list and keeps the k best couples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def merge_ref(vals_a, idx_a, vals_b, idx_b, k: int | None = None,
              valid_a=None, valid_b=None):
    """Merge two descending (vals, idx) k-lists along the last axis.

    Returns the top-k of the union, descending.  Ties are broken in favour
    of list ``a`` then lower position (stable lax.top_k over the concat).

    ``valid_a`` / ``valid_b``: optional boolean row masks over the
    leading axes — an invalid list contributes ``-inf`` values (its
    entries can never surface among real scores), so churned-out peers
    cost a select, not a branch.
    """
    if k is None:
        k = vals_a.shape[-1]
    if valid_a is not None:
        vals_a = jnp.where(valid_a[..., None], vals_a, -jnp.inf)
    if valid_b is not None:
        vals_b = jnp.where(valid_b[..., None], vals_b, -jnp.inf)
    # float lists merge in their OWN dtype (f64 for the x64 sweep, f32 /
    # bf16 for the reduced-precision mode — no silent upcast); non-float
    # and f16 inputs keep the historical float32 compute dtype
    dt = jnp.result_type(vals_a, vals_b)
    if not jnp.issubdtype(dt, jnp.floating) or dt == jnp.float16:
        dt = jnp.promote_types(dt, jnp.float32)
    v = jnp.concatenate([vals_a, vals_b], axis=-1).astype(dt)
    i = jnp.concatenate([idx_a, idx_b], axis=-1)
    mv, pos = jax.lax.top_k(v, k)
    mi = jnp.take_along_axis(i, pos, axis=-1)
    return mv, mi.astype(jnp.int32)
