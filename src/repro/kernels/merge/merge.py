"""Score-list merge Pallas TPU kernel (Merge-and-Backward phase).

Merges two descending k-lists into the top-k of their union using a
bitonic merge network: since ``concat(a, reverse(b))`` is bitonic, the
first k outputs of a bitonic sorting network of size 2k are obtained in
log2(2k) compare-exchange stages — O(k log k) work, fully vectorized,
no data-dependent control flow (MXU-free, pure VPU ops).

Both lists live entirely in VMEM (k is tiny: 8..256); the batch dim is the
grid.  Validated against ref.merge_ref in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import jaxcompat

NEG_INF = float("-inf")


def _next_pow2(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


def _bitonic_descending(v, i):
    """Full bitonic sort (descending) of (1, m) rows, m a power of two.

    Implemented with static stage/substage loops (log^2 m compare-exchange
    layers); each layer is a pair of where-selects over lane-shuffled copies
    — Mosaic-friendly, no gathers.
    """
    m = v.shape[1]
    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, m), 1)
    size = 2
    while size <= m:
        stride = size // 2
        while stride >= 1:
            partner = lanes ^ stride
            pv = _lane_swap(v, stride, m)
            pi = _lane_swap(i, stride, m)
            is_lo = (lanes & stride) == 0
            # direction: descending when the size-block index is even
            asc_block = (lanes & size) != 0
            # keep max at lo for descending blocks, min at lo for ascending
            take_max = jnp.logical_xor(is_lo, asc_block)
            gt = v > pv
            eq = v == pv
            lower_idx = lanes < partner
            # stable-ish tie-break: prefer element from lower lane
            win = jnp.where(eq, lower_idx, gt)
            keep = jnp.where(take_max, win, ~win)
            v = jnp.where(keep, v, pv)
            i = jnp.where(keep, i, pi)
            stride //= 2
        size *= 2
    return v, i


def _lane_swap(x, stride: int, m: int):
    """x with lanes permuted by XOR(stride) — via reshape/flip, no gather."""
    assert m % (2 * stride) == 0
    y = x.reshape((-1, m // (2 * stride), 2, stride))
    y = jnp.flip(y, axis=2)
    return y.reshape(x.shape)


def _merge_kernel(va_ref, ia_ref, vb_ref, ib_ref, *refs,
                  k: int, m: int, dt, masked: bool):
    if masked:
        ma_ref, mb_ref, vo_ref, io_ref = refs
    else:
        vo_ref, io_ref = refs
    va = va_ref[...].astype(dt)
    ia = ia_ref[...]
    vb = vb_ref[...].astype(dt)
    ib = ib_ref[...]
    if masked:
        # validity masking in VMEM: a dead peer's list becomes -inf rows
        # (it can never beat a live score) — pure select, no control flow
        va = jnp.where(ma_ref[...] != 0, va, NEG_INF)
        vb = jnp.where(mb_ref[...] != 0, vb, NEG_INF)
    pad = m // 2 - k
    if pad:
        va = jnp.pad(va, ((0, 0), (0, pad)), constant_values=NEG_INF)
        ia = jnp.pad(ia, ((0, 0), (0, pad)), constant_values=-1)
        vb = jnp.pad(vb, ((0, 0), (0, pad)), constant_values=NEG_INF)
        ib = jnp.pad(ib, ((0, 0), (0, pad)), constant_values=-1)
    v = jnp.concatenate([va, vb], axis=1)
    i = jnp.concatenate([ia, ib], axis=1)
    v, i = _bitonic_descending(v, i)
    vo_ref[...] = v[:, :k]
    io_ref[...] = i[:, :k]


@functools.partial(jax.jit, static_argnames=("interpret",))
def merge_pallas(vals_a, idx_a, vals_b, idx_b, *, interpret: bool = True,
                 valid_a=None, valid_b=None):
    """Merge two descending k-lists -> top-k of the union (descending).

    float64 inputs (the x64 simulator sweep, interpret mode) merge in
    float64; anything narrower keeps the float32 compute dtype.

    ``valid_a`` / ``valid_b``: optional boolean row masks over the
    leading axes (churned-out peers).  Masking happens inside the kernel
    on the VMEM-resident block — an invalid list's values become -inf
    before the bitonic network runs, identical to pre-masking the HBM
    input but without materializing a masked copy.
    """
    lead = vals_a.shape[:-1]
    k = vals_a.shape[-1]
    m = 2 * _next_pow2(k)
    dt = jnp.result_type(vals_a, vals_b)
    if not jnp.issubdtype(dt, jnp.floating) or dt == jnp.float16:
        # non-float / f16 inputs keep the historical f32 compute dtype;
        # f64, f32 and bf16 lists merge in their OWN dtype (the
        # reduced-precision sweep must not silently upcast bf16)
        dt = jnp.promote_types(dt, jnp.float32)
    va = vals_a.reshape((-1, k))
    b = va.shape[0]
    args = [va, idx_a.reshape((-1, k)), vals_b.reshape((-1, k)),
            idx_b.reshape((-1, k))]
    masked = valid_a is not None or valid_b is not None
    spec = pl.BlockSpec((1, k), lambda i: (i, 0))
    in_specs = [spec] * 4
    if masked:
        ones = jnp.ones(lead, jnp.int32)
        args.append((ones if valid_a is None
                     else valid_a.astype(jnp.int32)).reshape((-1, 1)))
        args.append((ones if valid_b is None
                     else valid_b.astype(jnp.int32)).reshape((-1, 1)))
        in_specs = in_specs + [pl.BlockSpec((1, 1), lambda i: (i, 0))] * 2
    kern = functools.partial(_merge_kernel, k=k, m=m, dt=dt, masked=masked)
    vo, io = pl.pallas_call(
        kern,
        grid=(b,),
        in_specs=in_specs,
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((b, k), dt),
                   jax.ShapeDtypeStruct((b, k), jnp.int32)],
        compiler_params=jaxcompat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(*args)
    return vo.reshape(lead + (k,)), io.reshape(lead + (k,))
