"""jit'd public wrapper for the score-list merge kernel."""
from __future__ import annotations

from repro.kernels.merge.merge import merge_pallas
from repro.kernels.merge.ref import merge_ref


def merge_scorelists(vals_a, idx_a, vals_b, idx_b, *, use_pallas: bool = False,
                     interpret: bool = True, valid_a=None, valid_b=None):
    """Merge-and-Backward: top-k of the union of two descending k-lists.

    ``valid_a`` / ``valid_b``: optional boolean row masks over the leading
    axes — an invalid (churned-out) list contributes -inf values instead
    of branching; see the churn sweep in ``repro.engine.sim_jax``.
    """
    if use_pallas:
        return merge_pallas(vals_a, idx_a, vals_b, idx_b,
                            interpret=interpret,
                            valid_a=valid_a, valid_b=valid_b)
    return merge_ref(vals_a, idx_a, vals_b, idx_b,
                     valid_a=valid_a, valid_b=valid_b)
