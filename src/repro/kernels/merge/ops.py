"""jit'd public wrapper for the score-list merge kernel."""
from __future__ import annotations

from repro.kernels.merge.merge import merge_pallas
from repro.kernels.merge.ref import merge_ref


def merge_scorelists(vals_a, idx_a, vals_b, idx_b, *, use_pallas: bool = False,
                     interpret: bool = True):
    """Merge-and-Backward: top-k of the union of two descending k-lists."""
    if use_pallas:
        return merge_pallas(vals_a, idx_a, vals_b, idx_b, interpret=interpret)
    return merge_ref(vals_a, idx_a, vals_b, idx_b)
