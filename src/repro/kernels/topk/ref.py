"""Pure-jnp oracle for the blocked local top-k kernel.

The paper's "Local Query Execution" phase: each peer scores its local data
items and keeps the k best (score, address) couples.  On TPU the "peer" is a
device and the "local data" a shard of scores (e.g. a vocab shard of logits);
the address is the global row index.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_ref(scores: jax.Array, k: int, index_offset: int = 0):
    """Top-k values and *global* indices of ``scores`` along the last axis.

    Args:
      scores: (..., n) array.
      k: number of winners, k <= n.
      index_offset: added to local indices to form global "addresses".

    Returns:
      (vals, idx): (..., k) descending values and int32 global indices.
      Ties broken by lowest index (lax.top_k semantics).
    """
    vals, idx = jax.lax.top_k(scores.astype(jnp.float32), k)
    return vals, (idx + index_offset).astype(jnp.int32)
