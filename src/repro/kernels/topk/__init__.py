from repro.kernels.topk.ops import local_topk  # noqa: F401
from repro.kernels.topk.ref import topk_ref  # noqa: F401
from repro.kernels.topk.topk import topk_pallas  # noqa: F401
