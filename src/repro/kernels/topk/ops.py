"""jit'd public wrapper for the local top-k kernel.

``local_topk`` dispatches to the Pallas kernel (interpret mode on CPU,
compiled on TPU) or the XLA reference, and always returns f32 values +
int32 global indices in descending order.
"""
from __future__ import annotations

import jax

from repro.kernels.topk.ref import topk_ref
from repro.kernels.topk.topk import topk_pallas


def local_topk(scores: jax.Array, k: int, *, index_offset: int = 0,
               use_pallas: bool = False, tile_n: int = 1024,
               interpret: bool = True):
    """Top-k (vals, global idx) of ``scores`` along the last axis.

    The paper's Local Query Execution: score local items, keep the k best
    couples.  ``index_offset`` turns local positions into global addresses
    (shard_offset = axis_index * shard_size).
    """
    if use_pallas:
        return topk_pallas(scores, k, tile_n=tile_n,
                           index_offset=index_offset, interpret=interpret)
    return topk_ref(scores, k, index_offset=index_offset)
