"""Blocked local top-k Pallas TPU kernel.

Streams HBM->VMEM tiles of a long score vector and maintains a running
k-list (values + global indices) in VMEM scratch, exactly the paper's
local-query-execution phase with bounded memory:

    for each tile t:                       # grid dim 1 (sequential)
        cand = concat(running_k, tile)     # (k + tile_n,)
        running_k = extract_top_k(cand)    # k iterations of max/argmax/mask

Design notes (TPU mapping):
  * tile_n is a multiple of 128 (lane dim) so loads are layout-friendly.
  * extraction uses only max / argmax-free (iota==pos) select ops — no sort,
    no gather — all Mosaic-lowerable vector primitives.
  * the running list lives in VMEM scratch and persists across the
    sequential grid dimension; output is written on the last tile.
  * numerically the kernel works in f32 regardless of input dtype (scores
    are compared, never accumulated, so f32 is exact for bf16/f16 inputs).

Validated against ref.topk_ref in interpret mode (CPU) across shape/dtype
sweeps; see tests/test_kernels_topk.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import jaxcompat

NEG_INF = float("-inf")


def _extract_topk(cand_v, cand_i, k: int):
    """k rounds of (max, first-argmax, mask) over the candidate row.

    cand_v: (1, m) f32, cand_v may contain -inf padding.
    cand_i: (1, m) i32 global indices.
    Returns (1, k) f32 values (descending) and (1, k) i32 indices.
    """
    m = cand_v.shape[1]
    c_iota = jax.lax.broadcasted_iota(jnp.int32, (1, m), 1)
    k_iota = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)

    def body(j, carry):
        cv, rv, ri = carry
        mx = jnp.max(cv, axis=1, keepdims=True)                     # (1,1)
        # first position attaining the max (tie-break: lowest index)
        is_max = cv == mx
        pos = jnp.min(jnp.where(is_max, c_iota, m), axis=1, keepdims=True)
        sel = c_iota == pos
        gi = jnp.sum(jnp.where(sel, cand_i, 0), axis=1, keepdims=True)
        rv = jnp.where(k_iota == j, mx, rv)
        ri = jnp.where(k_iota == j, gi, ri)
        cv = jnp.where(sel, NEG_INF, cv)
        return cv, rv, ri

    rv0 = jnp.full((1, k), NEG_INF, jnp.float32)
    ri0 = jnp.full((1, k), -1, jnp.int32)
    _, rv, ri = jax.lax.fori_loop(0, k, body, (cand_v, rv0, ri0))
    return rv, ri


def _topk_kernel(x_ref, vals_ref, idx_ref, run_v, run_i, *,
                 k: int, tile_n: int, n_tiles: int, n_valid: int,
                 index_offset: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        run_v[...] = jnp.full((1, k), NEG_INF, jnp.float32)
        run_i[...] = jnp.full((1, k), -1, jnp.int32)

    x = x_ref[...].astype(jnp.float32)                               # (1, tile_n)
    local = t * tile_n + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    x = jnp.where(local < n_valid, x, NEG_INF)                       # mask pad
    gidx = local + index_offset

    cand_v = jnp.concatenate([run_v[...], x], axis=1)
    cand_i = jnp.concatenate([run_i[...], gidx], axis=1)
    rv, ri = _extract_topk(cand_v, cand_i, k)
    run_v[...] = rv
    run_i[...] = ri

    @pl.when(t == n_tiles - 1)
    def _out():
        vals_ref[...] = rv
        idx_ref[...] = ri


@functools.partial(jax.jit, static_argnames=("k", "tile_n", "interpret",
                                             "index_offset"))
def topk_pallas(scores: jax.Array, k: int, *, tile_n: int = 1024,
                index_offset: int = 0, interpret: bool = True):
    """Blocked top-k over the last axis of ``scores`` (any leading batch).

    Returns (vals f32 (..., k), idx i32 (..., k)) in descending value order.
    """
    if scores.ndim == 1:
        v, i = topk_pallas(scores[None], k, tile_n=tile_n,
                           index_offset=index_offset, interpret=interpret)
        return v[0], i[0]
    lead = scores.shape[:-1]
    n = scores.shape[-1]
    if k > n:
        raise ValueError(f"k={k} > n={n}")
    x = scores.reshape((-1, n))
    b = x.shape[0]
    n_tiles = max(1, -(-n // tile_n))
    n_pad = n_tiles * tile_n
    if n_pad != n:
        x = jnp.pad(x, ((0, 0), (0, n_pad - n)), constant_values=NEG_INF)

    kern = functools.partial(
        _topk_kernel, k=k, tile_n=tile_n, n_tiles=n_tiles, n_valid=n,
        index_offset=index_offset)
    vals, idx = pl.pallas_call(
        kern,
        grid=(b, n_tiles),
        in_specs=[pl.BlockSpec((1, tile_n), lambda i, t: (i, t))],
        out_specs=[pl.BlockSpec((1, k), lambda i, t: (i, 0)),
                   pl.BlockSpec((1, k), lambda i, t: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((b, k), jnp.float32),
                   jax.ShapeDtypeStruct((b, k), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((1, k), jnp.float32),
                        pltpu.VMEM((1, k), jnp.int32)],
        compiler_params=jaxcompat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x)
    return vals.reshape(lead + (k,)), idx.reshape(lead + (k,))
