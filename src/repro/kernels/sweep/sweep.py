"""Pallas kernels for the per-depth gather / wait-propagation hot loop.

Two kernels, both gridded over the entry axis (one program per query
entry, embarrassingly parallel):

  * ``arrivals_pallas`` — the forward flood's fused gather+add: each
    program gathers its entry's parent-level arrival row through the
    static ``par_pos`` index vector and adds the level's downstream
    link terms, producing the level's arrival row in one VMEM pass.
  * ``wait_pallas`` — the Appendix-A send-time rule
    ``min(max(own_ready, all_in), max(deadline, own_ready))`` fused
    into one elementwise pass; the churn variant additionally emits the
    liveness-masked send time (``inf`` for a peer dead at its send
    time) so the mask costs no extra memory round trip.

Both preserve the input dtype exactly (f64 / f32 / bf16 — the
reduced-precision mode relies on no silent upcast) and group their
float ops exactly as the jnp oracles in ``ref.py``, so the f64 path
keeps the repo's bit-parity contract.  ``interpret=True`` runs the
kernels through the Pallas interpreter — the CPU CI path; on TPU the
same code compiles to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import jaxcompat


def _arrivals_kernel(pp_ref, tq_ref, dn_ref, o_ref):
    # one entry row: gather the parent level's arrivals through the
    # static parent-position vector, add this level's link terms
    o_ref[0, :] = (jnp.take(tq_ref[0, :], pp_ref[0, :], axis=0)
                   + dn_ref[0, :])


@functools.partial(jax.jit, static_argnames=("interpret",))
def arrivals_pallas(tq_prev, dn, par_pos, *, interpret: bool = True):
    """Level arrivals ``tq_prev[:, par_pos] + dn`` as a Pallas kernel.

    ``tq_prev`` (E, L_prev), ``dn`` (E, L), ``par_pos`` (L,) int.
    Returns (E, L) in ``result_type(tq_prev, dn)`` — same promotion as
    the jnp expression, so f64 stays f64 and bf16 stays bf16.
    """
    E, Lp = tq_prev.shape
    L = dn.shape[1]
    dt = jnp.result_type(tq_prev, dn)
    pp = jnp.asarray(par_pos, jnp.int32).reshape(1, L)
    return pl.pallas_call(
        _arrivals_kernel,
        grid=(E,),
        in_specs=[pl.BlockSpec((1, L), lambda i: (0, 0)),
                  pl.BlockSpec((1, Lp), lambda i: (i, 0)),
                  pl.BlockSpec((1, L), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, L), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((E, L), dt),
        compiler_params=jaxcompat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret)(pp, tq_prev.astype(dt), dn.astype(dt))


def _wait_kernel(r_ref, a_ref, d_ref, o_ref):
    own = r_ref[0, :]
    o_ref[0, :] = jnp.minimum(jnp.maximum(own, a_ref[0, :]),
                              jnp.maximum(d_ref[0, :], own))


def _wait_churn_kernel(r_ref, a_ref, d_ref, death_ref, s_ref, snd_ref):
    own = r_ref[0, :]
    s = jnp.minimum(jnp.maximum(own, a_ref[0, :]),
                    jnp.maximum(d_ref[0, :], own))
    s_ref[0, :] = s
    # dead at send time -> an arrival that can never release a parent
    snd_ref[0, :] = jnp.where(death_ref[0, :] >= s, s, jnp.inf)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wait_pallas(own_ready, all_in, deadline, death=None, *,
                interpret: bool = True):
    """Appendix-A send times as a Pallas kernel (optionally churned).

    All operands (E, L), dtype preserved.  Without ``death`` returns
    the raw send time ``s``; with ``death`` returns ``(s, send)`` where
    ``send`` is ``s`` masked to ``inf`` for peers dead at their send
    time — the exact fill the churn sweep commits.
    """
    E, L = own_ready.shape
    dt = jnp.result_type(own_ready, all_in, deadline)
    spec = pl.BlockSpec((1, L), lambda i: (i, 0))
    params = jaxcompat.pallas_tpu_compiler_params(
        dimension_semantics=("parallel",))
    args = (own_ready.astype(dt), all_in.astype(dt), deadline.astype(dt))
    if death is None:
        return pl.pallas_call(
            _wait_kernel, grid=(E,), in_specs=[spec] * 3, out_specs=spec,
            out_shape=jax.ShapeDtypeStruct((E, L), dt),
            compiler_params=params, interpret=interpret)(*args)
    out = pl.pallas_call(
        _wait_churn_kernel, grid=(E,), in_specs=[spec] * 4,
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((E, L), dt),
                   jax.ShapeDtypeStruct((E, L), dt)],
        compiler_params=params,
        interpret=interpret)(*args, death.astype(dt))
    return tuple(out)
