"""jnp oracles for the per-depth forward-sweep kernels.

These are the EXACT expressions ``repro.engine.sim_jax._fd_sweep`` has
always used — extracted verbatim so the Pallas kernels in
:mod:`repro.kernels.sweep.sweep` have a bit-parity reference: same
gather-then-add grouping for arrivals, same min/max grouping for the
Appendix-A wait rule.  Dtypes are preserved (f64 under ``enable_x64``,
f32 / bf16 in the reduced-precision mode) — no silent upcasts.
"""
from __future__ import annotations

import jax.numpy as jnp


def arrivals_ref(tq_prev, dn, par_pos):
    """Level-d query arrival times from level d-1's.

    ``tq_prev`` — (E, L_prev) arrival times of the parent level;
    ``dn`` — (E, L) this level's downstream link terms (already gathered
    to level order); ``par_pos`` — (L,) each node's parent position
    inside the parent level.  Returns (E, L):
    ``tq_prev[:, par_pos] + dn`` — the fused gather+add of the forward
    flood.
    """
    return tq_prev[:, par_pos] + dn


def wait_ref(own_ready, all_in, deadline):
    """Appendix-A send-time rule, elementwise over (E, L).

    ``s = min(max(own_ready, all_in), max(deadline, own_ready))`` — a
    peer sends when its own execution AND every child arrival are in,
    capped by its TTL deadline, but never before its own list is ready.
    The grouping matches the numpy sweep exactly (bit-parity in f64).
    """
    return jnp.minimum(jnp.maximum(own_ready, all_in),
                       jnp.maximum(deadline, own_ready))
