"""Dispatch layer for the forward-sweep kernels (mirrors merge/ops.py).

``use_pallas=False`` routes to the jnp oracles (what XLA:CPU fuses
best); ``use_pallas=True`` routes to the Pallas kernels —
``interpret=True`` for the CPU CI path, compiled Mosaic on TPU.  Both
paths produce the same bits in f64 and preserve f32 / bf16 dtypes.
"""
from __future__ import annotations

from repro.kernels.sweep.ref import arrivals_ref, wait_ref
from repro.kernels.sweep.sweep import arrivals_pallas, wait_pallas


def level_arrivals(tq_prev, dn, par_pos, *, use_pallas: bool = False,
                   interpret: bool = True):
    """Level-d arrival times ``tq_prev[:, par_pos] + dn``."""
    if use_pallas:
        return arrivals_pallas(tq_prev, dn, par_pos, interpret=interpret)
    return arrivals_ref(tq_prev, dn, par_pos)


def wait_propagate(own_ready, all_in, deadline, *, death=None,
                   use_pallas: bool = False, interpret: bool = True):
    """Appendix-A send times; with ``death`` also the churn-masked send.

    Returns ``s`` (E, L), or ``(s, send)`` when ``death`` is given,
    with ``send = where(death >= s, s, inf)``.
    """
    if use_pallas:
        return wait_pallas(own_ready, all_in, deadline, death,
                           interpret=interpret)
    import jax.numpy as jnp
    s = wait_ref(own_ready, all_in, deadline)
    if death is None:
        return s
    return s, jnp.where(death >= s, s, jnp.inf)
