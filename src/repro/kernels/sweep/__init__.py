from repro.kernels.sweep.ops import level_arrivals, wait_propagate  # noqa: F401
from repro.kernels.sweep.ref import arrivals_ref, wait_ref  # noqa: F401
from repro.kernels.sweep.sweep import (arrivals_pallas,  # noqa: F401
                                       wait_pallas)
