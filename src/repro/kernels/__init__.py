from repro.kernels.merge import merge_pallas, merge_ref, merge_scorelists  # noqa: F401
from repro.kernels.sweep import level_arrivals, wait_propagate  # noqa: F401
from repro.kernels.topk import local_topk, topk_pallas, topk_ref  # noqa: F401
