"""Config system for the repro framework.

Every assigned architecture is a ``ModelConfig`` instance registered under its
``--arch`` id.  Input shapes are ``ShapeConfig`` instances; the cross product
(arch x shape) defines the dry-run / roofline cells.

Nothing in this module touches jax device state — configs must be importable
before the dry-run sets XLA_FLAGS.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Callable, Optional

# --------------------------------------------------------------------------
# Model configuration
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0          # per-expert FFN hidden size
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3 style)."""
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class RecurrentConfig:
    """RG-LRU (Griffin) / RWKV-6 recurrent-mixer parameters."""
    lru_width: int = 0          # RG-LRU channel width (griffin)
    conv_width: int = 4         # temporal conv width (griffin)
    rwkv_head_dim: int = 64     # RWKV-6 per-head dim
    chunk_size: int = 128       # chunked-scan chunk length (training/prefill)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads

    # token mixer selection, cycled over layers, e.g. ("rec","rec","attn")
    mixer_pattern: tuple = ("attn",)
    attn_kind: str = "gqa"      # gqa | mla
    qkv_bias: bool = False
    local_window: int = 0       # >0: sliding-window attention
    rope_theta: float = 10000.0
    mrope_sections: Optional[tuple] = None   # qwen2-vl M-RoPE (t,h,w) sections
    pos_kind: str = "rope"      # rope | learned | none

    act: str = "swiglu"         # swiglu | gelu
    norm: str = "rms"           # rms | ln
    tie_embeddings: bool = False

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    recurrent: Optional[RecurrentConfig] = None

    # encoder-decoder (whisper): stubbed modality frontend provides encoder
    # inputs as precomputed frame embeddings of shape (B, encoder_seq, d_model)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 0

    # dtype policy
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # notes carried into DESIGN/EXPERIMENTS tables
    source: str = ""

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def padded_vocab(self, multiple: int = 2048) -> int:
        return int(math.ceil(self.vocab_size / multiple) * multiple)

    @property
    def is_attention_free(self) -> bool:
        return all(m != "attn" for m in self.mixer_pattern)

    @property
    def supports_long_context(self) -> bool:
        """True when decode state is O(1) or windowed (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    def layer_kinds(self) -> tuple:
        """Mixer kind for each decoder layer (pattern cycled)."""
        p = self.mixer_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    # ---- parameter counting (for MODEL_FLOPS = 6*N*D roofline term) ----
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        v = self.padded_vocab()

        def attn_params() -> int:
            if self.attn_kind == "mla":
                m = self.mla
                qk_dim = m.qk_nope_dim + m.qk_rope_dim
                p = d * m.q_lora_rank + m.q_lora_rank * n_q * qk_dim
                p += d * (m.kv_lora_rank + m.qk_rope_dim)
                p += m.kv_lora_rank * n_q * (m.qk_nope_dim + m.v_head_dim)
                p += n_q * m.v_head_dim * d
                return p
            p = d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d
            if self.qkv_bias:
                p += (n_q + 2 * n_kv) * hd
            return p

        def rec_params(kind: str) -> int:
            r = self.recurrent
            if kind == "rwkv":
                # r,k,v,g,o projections + decay/first params + token-shift mixes
                return 5 * d * d + 4 * d + 2 * d * 32  # lora decay approx
            # griffin RG-LRU block: in-proj (2x lru), conv, gates, out-proj
            lw = r.lru_width or d
            return d * 2 * lw + r.conv_width * lw + 2 * lw * lw // 8 + lw * d + 2 * lw

        def ffn_params() -> int:
            if self.moe is not None:
                e = self.moe
                per = 3 * d * e.d_expert if self.act == "swiglu" else 2 * d * e.d_expert
                router = d * e.n_experts
                n_e = (e.top_k + e.n_shared_experts) if active_only else (
                    e.n_experts + e.n_shared_experts)
                return per * n_e + router
            mult = 3 if self.act == "swiglu" else 2
            return mult * d * self.d_ff

        total = v * d * (1 if self.tie_embeddings else 2)
        for kind in self.layer_kinds():
            mixer = attn_params() if kind == "attn" else rec_params(kind)
            total += mixer + ffn_params() + 2 * d  # + norms
        if self.is_encoder_decoder:
            # encoder self-attn + ffn, and decoder cross-attn
            enc = self.n_encoder_layers * (attn_params() + ffn_params() + 2 * d)
            cross = self.n_layers * attn_params()
            total += enc + cross
        return int(total)


# --------------------------------------------------------------------------
# Shapes
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """Assignment rule: long_500k only for sub-quadratic (ssm/hybrid) archs."""
    if shape.name == "long_500k":
        return cfg.supports_long_context
    return True


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg_fn: Callable[[], ModelConfig]):
    cfg = cfg_fn()
    _REGISTRY[cfg.name] = cfg
    return cfg_fn


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    # import arch modules for registration side effects
    if _REGISTRY:
        return
    from repro.configs import archs  # noqa: F401


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    changes: dict = dict(
        n_layers=min(cfg.n_layers, 2),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        param_dtype="float32",
        compute_dtype="float32",
    )
    if cfg.moe is not None:
        changes["moe"] = MoEConfig(
            n_experts=4, top_k=min(cfg.moe.top_k, 2), d_expert=64,
            n_shared_experts=min(cfg.moe.n_shared_experts, 1))
    if cfg.mla is not None:
        changes["mla"] = MLAConfig(q_lora_rank=48, kv_lora_rank=32,
                                   qk_nope_dim=16, qk_rope_dim=16, v_head_dim=16)
    if cfg.recurrent is not None:
        changes["recurrent"] = dataclasses.replace(
            cfg.recurrent, lru_width=128 if cfg.recurrent.lru_width else 0,
            rwkv_head_dim=32, chunk_size=16)
    if cfg.is_encoder_decoder:
        changes["n_encoder_layers"] = 2
        changes["encoder_seq"] = 16
    if cfg.local_window:
        changes["local_window"] = 32
    if cfg.mrope_sections is not None:
        changes["mrope_sections"] = (8, 4, 4)
    return dataclasses.replace(cfg, **changes)
