from repro.configs.base import (  # noqa: F401
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RecurrentConfig,
    SHAPES,
    ShapeConfig,
    get_config,
    list_archs,
    shape_applicable,
    smoke_config,
)
