"""qwen2-vl-72b — VLM backbone (text transformer only; ViT frontend is a stub).

[arXiv:2409.12191; hf]  80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064, M-RoPE (t,h,w) = (16,24,24) over head_dim 128.
"""
from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24),
        source="arXiv:2409.12191",
    )
