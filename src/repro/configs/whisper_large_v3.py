"""whisper-large-v3 — encoder-decoder audio transformer backbone.

[arXiv:2212.04356; unverified]  32L(enc)+32L(dec) d_model=1280 20H (kv=20)
d_ff=5120 vocab=51866.  The conv/mel frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings of shape (B, 1500, 1280).
"""
from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        family="audio",
        n_layers=32,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        head_dim=64,
        d_ff=5120,
        vocab_size=51866,
        act="gelu",
        norm="ln",
        pos_kind="learned",
        qkv_bias=True,
        is_encoder_decoder=True,
        n_encoder_layers=32,
        encoder_seq=1500,
        source="arXiv:2212.04356",
    )
