"""moonshot-v1-16b-a3b (Moonlight-16B-A3B) — MoE, 64 experts top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf]  48L d_model=2048 16H (GQA kv=16)
d_ff=1408 (per expert) vocab=163840, MoE 64e top-6 (+2 shared experts,
DeepSeek-style).
"""
from repro.configs.base import ModelConfig, MoEConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab_size=163840,
        rope_theta=50_000.0,
        moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared_experts=2),
        source="hf:moonshotai/Moonlight-16B-A3B",
    )
