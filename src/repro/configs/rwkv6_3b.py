"""rwkv6-3b (Finch) — attention-free, data-dependent decay linear recurrence.

[arXiv:2404.05892; hf]  32L d_model=2560 d_ff=8960 vocab=65536, head_dim 64.
"""
from repro.configs.base import ModelConfig, RecurrentConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        family="ssm",
        n_layers=32,
        d_model=2560,
        n_heads=40,             # 2560 / 64 rwkv heads
        n_kv_heads=40,
        head_dim=64,
        d_ff=8960,
        vocab_size=65536,
        mixer_pattern=("rwkv",),
        pos_kind="none",
        act="rwkv_channel_mix",
        norm="ln",
        recurrent=RecurrentConfig(rwkv_head_dim=64, chunk_size=128),
        source="arXiv:2404.05892",
    )
