"""Import all assigned-architecture configs for registry side effects."""
from repro.configs import (  # noqa: F401
    granite_moe_1b_a400m,
    minicpm3_4b,
    moonshot_v1_16b_a3b,
    phi3_medium_14b,
    qwen1_5_0_5b,
    qwen2_0_5b,
    qwen2_vl_72b,
    recurrentgemma_2b,
    rwkv6_3b,
    whisper_large_v3,
)
