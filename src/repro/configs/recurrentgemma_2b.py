"""recurrentgemma-2b (Griffin) — RG-LRU + local attention, pattern 2:1.

[arXiv:2402.19427; hf]  26L d_model=2560 10H (MQA kv=1, head_dim 256)
d_ff=7680 vocab=256000, local window 2048, lru_width 2560.
"""
from repro.configs.base import ModelConfig, RecurrentConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256000,
        mixer_pattern=("rglru", "rglru", "attn"),
        local_window=2048,
        act="swiglu",   # GeGLU in the paper; gated-linear either way
        rope_theta=10_000.0,
        recurrent=RecurrentConfig(lru_width=2560, conv_width=4, chunk_size=128),
        source="arXiv:2402.19427",
    )
