import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: ``.lower().compile()`` every (arch × shape × mesh).

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices build the production meshes
(16×16 single-pod, 2×16×16 multi-pod); every cell must lower, SPMD-
partition, and compile.  ``memory_analysis()`` proves the per-device
footprint, ``cost_analysis()`` + the HLO collective parser feed the
roofline table (EXPERIMENTS.md §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
      --shape train_4k [--multi-pod] [--out artifacts/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]
"""
import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (SHAPES, ModelConfig, ShapeConfig, get_config,
                                list_archs, shape_applicable)
from repro import jaxcompat
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.optim.sharding import (batch_axes, decode_state_specs,
                                  input_specs_pytree, opt_state_specs,
                                  param_specs)
from repro.roofline.analysis import (HW, model_flops_estimate,
                                     roofline_terms)
from repro.roofline.hlo_parse import analyze as hlo_analyze
from repro.runtime.steps import (make_prefill_step, make_serve_step,
                                 make_train_step)

DEFAULT_OUT = "artifacts/dryrun"
ACT_BUDGET_BYTES = 4 * 2 ** 30      # boundary-activation budget per device


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — no allocation, weak-type clean)
# --------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract model inputs for a cell (tokens/labels + modality stubs)."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        toks = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        out = {"tokens": toks}
        return out
    out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if shape.kind == "train":
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.is_encoder_decoder:
        out["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.mrope_sections is not None:
        out["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, min(256, s), cfg.d_model), jnp.float32)
    return out


def pick_microbatches(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    """Smallest power-of-two microbatch count keeping per-device layer-
    boundary activations under ACT_BUDGET_BYTES (scan + full remat)."""
    mesh_shape = dict(mesh.shape)
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    per_dev = max(shape.global_batch // dp, 1)
    n_layers = cfg.n_layers + cfg.n_encoder_layers
    bnd = per_dev * shape.seq_len * cfg.d_model * 2 * n_layers
    m = 1
    while bnd // m > ACT_BUDGET_BYTES and m < per_dev:
        m *= 2
    return m


# --------------------------------------------------------------------------
# one cell
# --------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             hw: HW = HW(), verbose: bool = True,
             overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not shape_applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "long_500k needs sub-quadratic decode state "
                          "(ssm/hybrid only) — DESIGN.md §5"}
    overrides = overrides or {}
    if "chunk_size" in overrides and cfg.recurrent is not None:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, recurrent=_dc.replace(
            cfg.recurrent, chunk_size=overrides["chunk_size"]))
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_shape = dict(mesh.shape)
    baxes = batch_axes(mesh_shape)
    t0 = time.time()

    max_seq = shape.seq_len if shape.kind != "decode" else shape.seq_len
    params_abs = jax.eval_shape(
        lambda k: M.init_params(k, cfg, max_seq=max(max_seq, 4096)),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    pspecs = param_specs(params_abs, cfg, mesh)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)

    batch_abs = input_specs(cfg, shape)
    bshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          input_specs_pytree(batch_abs, mesh))

    record = {"arch": arch, "shape": shape_name,
              "mesh": ("2x16x16" if multi_pod else "16x16"),
              "kind": shape.kind, "skipped": False}

    # the mesh context makes every with_sharding_constraint in the model
    # real during tracing (without it they are silent no-ops and SPMD
    # propagation is free to replicate activations)
    mesh_ctx = jaxcompat.use_mesh(mesh)
    mesh_ctx.__enter__()
    if shape.kind == "train":
        microbatches = overrides.get(
            "microbatches", pick_microbatches(cfg, shape, mesh))
        record["microbatches"] = microbatches
        opt_abs = jax.eval_shape(
            functools.partial(adamw_init, cfg=AdamWConfig()), params_abs)
        ospecs = opt_state_specs(params_abs, cfg, mesh)
        oshard = type(opt_abs)(
            NamedSharding(mesh, P()),
            jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs),
            jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs))
        step = make_train_step(
            cfg, AdamWConfig(), microbatches=microbatches,
            remat=overrides.get("remat", "full"), batch_axes=baxes,
            q_block=overrides.get("q_block", 1024),
            kv_block=overrides.get("kv_block", 1024),
            acc_specs=(jax.tree.map(lambda s: NamedSharding(mesh, s),
                                    ospecs)
                       if microbatches > 1 else None))
        jitted = jax.jit(step,
                         in_shardings=(pshard, oshard, bshard),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_abs, opt_abs, batch_abs)
    elif shape.kind == "prefill":
        step = make_prefill_step(
            cfg, q_block=overrides.get("q_block", 1024),
            kv_block=overrides.get("kv_block", 1024))
        jitted = jax.jit(step, in_shardings=(pshard, bshard))
        lowered = jitted.lower(params_abs, batch_abs)
    else:  # decode
        state_abs = jax.eval_shape(
            functools.partial(M.init_decode_state, cfg,
                              batch=shape.global_batch,
                              s_max=shape.seq_len),)
        sspecs = decode_state_specs(state_abs, cfg, mesh,
                                    s_max=shape.seq_len)
        sshard = jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs)
        step = make_serve_step(
            cfg, mesh, k=overrides.get("k", 20),
            algorithm=overrides.get("algorithm", "fd"),
            schedule=overrides.get("schedule", "halving"),
            batch_axes=baxes)
        toks_abs = input_specs(cfg, shape)["tokens"]
        rng_abs = jax.ShapeDtypeStruct((2,), jnp.uint32)
        jitted = jax.jit(step,
                         in_shardings=(pshard, sshard,
                                       NamedSharding(
                                           mesh,
                                           input_specs_pytree(
                                               {"t": toks_abs},
                                               mesh)["t"]),
                                       NamedSharding(mesh, P())),
                         donate_argnums=(1,))
        lowered = jitted.lower(params_abs, state_abs, toks_abs, rng_abs)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    mesh_ctx.__exit__(None, None, None)
    t_compile = time.time() - t0 - t_lower
    record.update(t_lower_s=round(t_lower, 1), t_compile_s=round(t_compile, 1))

    # ---- analysis -------------------------------------------------------
    try:
        mem = compiled.memory_analysis()
        record["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
        args_b = record["memory"].get("argument_size_in_bytes", 0)
        temp_b = record["memory"].get("temp_size_in_bytes", 0)
        record["memory"]["per_device_total_gib"] = round(
            (args_b + temp_b) / 2 ** 30, 3)
    except Exception as e:                                     # noqa: BLE001
        record["memory"] = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        record["xla_cost_analysis"] = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "note": "counts scan bodies ONCE - see hlo_parse totals"}
    except Exception as e:                                     # noqa: BLE001
        record["xla_cost_analysis"] = {"error": str(e)}
    try:
        # trip-count-weighted totals from the per-device SPMD module
        totals = hlo_analyze(compiled.as_text())
        record["flops"] = totals.flops
        record["hlo_bytes"] = totals.bytes_accessed
        record["convert_bytes_cpu_artifact"] = totals.convert_bytes
        record["collective"] = {
            "total": totals.collective_bytes,
            "by_op": totals.coll_by_op,
            "counts": totals.coll_counts}
        record["while_trip_counts"] = totals.trip_counts
    except Exception as e:                                     # noqa: BLE001
        record["flops"], record["hlo_bytes"] = 0.0, 0.0
        record["collective"] = {"total": 0, "error": str(e)}

    chips = 512 if multi_pod else 256
    mf = model_flops_estimate(cfg, shape, mode=shape.kind)
    terms = roofline_terms(
        hlo_flops=record["flops"], hlo_bytes=record["hlo_bytes"],
        collective_bytes=record["collective"].get("total", 0),
        hw=hw, model_flops=mf, chips=chips)
    record["roofline"] = terms
    if verbose:
        print(f"[{record['mesh']}] {arch} × {shape_name}: "
              f"compile {t_compile:.0f}s  "
              f"mem/dev {record['memory'].get('per_device_total_gib', '?')} GiB  "
              f"compute {terms['compute_s']:.3e}s mem {terms['memory_s']:.3e}s "
              f"coll {terms['collective_s']:.3e}s → {terms['dominant']}  "
              f"roofline {terms.get('roofline_frac', 0):.1%}")
    return record


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", dest="mp", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()

    cells = []
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.mp]
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
                path = os.path.join(args.out, tag + ".json")
                try:
                    rec = run_cell(arch, shape, multi_pod=mp)
                except Exception as e:                         # noqa: BLE001
                    failures += 1
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "error": str(e),
                           "traceback": traceback.format_exc()}
                    print(f"FAIL {tag}: {e}")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                cells.append(rec)
    ok = sum(1 for c in cells if not c.get("error") and not c.get("skipped"))
    sk = sum(1 for c in cells if c.get("skipped"))
    print(f"\ndry-run: {ok} compiled, {sk} skipped (structural), "
          f"{failures} failed, artifacts in {args.out}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
