"""End-to-end training driver: data -> sharded train_step -> checkpoints,
with fault tolerance (resume-from-latest, straggler watchdog, recovery).

Runs on whatever devices exist (CPU smoke: ``--smoke``), and on the
production mesh unchanged — the sharding rules adapt to the mesh.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import jaxcompat
from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import get_config, smoke_config
from repro.data.pipeline import SyntheticLM, device_put_batch, extra_model_inputs
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.optim.sharding import batch_axes, param_specs
from repro.runtime.ft import StragglerWatchdog, run_with_recovery
from repro.runtime.steps import make_train_step


def build(arch: str, *, smoke: bool, batch: int, seq: int, model_par: int,
          microbatches: int, remat: str, lr: float, steps: int):
    cfg = get_config(arch)
    if smoke:
        cfg = smoke_config(cfg)
    mesh = make_host_mesh(model=model_par)
    opt_cfg = AdamWConfig(lr=lr, total_steps=steps,
                          warmup_steps=max(steps // 20, 1))

    ctx = jaxcompat.use_mesh(mesh)
    ctx.__enter__()
    key = jax.random.PRNGKey(0)
    params_abs = jax.eval_shape(
        lambda k: M.init_params(k, cfg, max_seq=max(seq, 128)), key)
    pspecs = param_specs(params_abs, cfg, mesh)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    params = jax.jit(
        lambda k: M.init_params(k, cfg, max_seq=max(seq, 128)),
        out_shardings=pshard)(key)
    opt_state = jax.jit(
        functools.partial(adamw_init, cfg=opt_cfg),
        out_shardings=type(adamw_init(params_abs, opt_cfg))(
            NamedSharding(mesh, P()), pshard, pshard))(params)

    baxes = batch_axes(dict(mesh.shape))
    step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                      microbatches=microbatches,
                                      remat=remat, batch_axes=baxes),
                      donate_argnums=(0, 1))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq,
                       global_batch=batch)
    return cfg, mesh, params, opt_state, step_fn, data, ctx


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none",
                    choices=("none", "full", "dots"))
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--watchdog", action="store_true")
    args = ap.parse_args()

    cfg, mesh, params, opt_state, step_fn, data, ctx = build(
        args.arch, smoke=args.smoke, batch=args.batch, seq=args.seq,
        model_par=args.model_par, microbatches=args.microbatches,
        remat=args.remat, lr=args.lr, steps=args.steps)
    print(f"arch={cfg.name} params={M.count_params(params):,} "
          f"mesh={dict(mesh.shape)} devices={len(jax.devices())}")

    mgr = None
    start = 0
    state = (params, opt_state)
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, save_every=args.ckpt_every)
        got_step, got = mgr.restore_latest(state)
        if got is not None:
            start, state = got_step, got
            print(f"resumed from step {start}")

    t0 = time.time()
    losses = []

    def one_step(step, st):
        params, opt_state = st
        raw = data.batch_at(step)
        raw = extra_model_inputs(cfg, raw)
        batch = device_put_batch(raw, mesh)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d}  loss {loss:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}  {dt:.1f}s")
        return params, opt_state

    wd = StragglerWatchdog(factor=20.0) if args.watchdog else None
    state = run_with_recovery(
        one_step, state, n_steps=args.steps, ckpt_manager=mgr,
        restore_fn=(lambda: mgr.restore_latest(state)) if mgr else None,
        watchdog=wd, start_step=start)
    ctx.__exit__(None, None, None)
    print(f"done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
