"""Production mesh construction.

Axis semantics (DESIGN.md §6):
  * ``data``  — batch + FSDP (ZeRO-3) axis, ICI within a pod
  * ``model`` — tensor-parallel axis (heads / d_ff / experts / vocab), ICI
  * ``pod``   — multi-pod data axis over DCN; gradient all-reduce crosses
                it once per step (optionally FD top-k compressed)

A FUNCTION, not a module constant — importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).

Mesh construction goes through ``repro.jaxcompat`` so the same code runs
on 0.4.x jaxlibs (no ``axis_types``) and ≥0.6 (explicit auto axes).
"""
from __future__ import annotations

import jax

from repro import jaxcompat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"{need} devices required (have {len(devices)}); the dry-run "
            "sets XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import")
    return jaxcompat.make_mesh(shape, axes, devices=devices[:need])


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / examples).

    ``model`` is clamped to the device count (a 1-device CPU host still
    runs every example, just without real model parallelism)."""
    n = len(jax.devices())
    model = max(1, min(model, n))
    data = max(1, n // model)
    return jaxcompat.make_mesh((data, model), ("data", "model"))
