"""Serving entrypoints: always-on overlay query serving + LM decode.

Two subcommands share this launcher:

``overlay`` — the paper-shaped service: a long-lived
:class:`repro.engine.QueryServer` hosting warm ``SimEngine`` instances
(one per requested topology), dynamically batching concurrent
``QuerySpec`` streams onto shared jitted sweeps and reporting serving
metrics (throughput, latency percentiles, batch histogram).

  PYTHONPATH=src python -m repro.launch.serve overlay \
      --topology ba --n-peers 2000 --backend jax \
      --policies fd-dynamic,cn --requests 256 --concurrency 16

``decode`` — the LM end-to-end path: prefill + decode where every decode
step executes a Top-k "query" over the model-sharded vocab axis using
the FD merge-and-backward.  ``--policy`` selects a member of the
``repro.engine`` registry (``fd-dynamic`` / ``cn`` / ``cn-star``); the
legacy ``--algorithm cn|cn_star`` flag still works and is mapped onto a
policy (benchmarks/tpu_comm uses this).

  PYTHONPATH=src python -m repro.launch.serve decode --arch qwen2-0.5b \
      --smoke --batch 4 --prompt-len 32 --gen 16

Flag-style invocations without a subcommand (``... serve --arch ...``)
keep routing to ``decode`` for back compatibility.
"""
from __future__ import annotations

import argparse
import time


def state_from_prefill(cfg, prefill_state, s_max: int,
                       cache_dtype=None):
    """Convert prompt-length caches into pre-sized decode caches (pad the
    seq dim to s_max; window caches wrap the last W positions)."""
    import jax
    import jax.numpy as jnp

    from repro.models import attention as A
    from repro.models import model as M

    if cache_dtype is None:
        cache_dtype = jnp.float32
    pos = int(prefill_state.pos)

    def _pad_seq(a, axis: int, target: int):
        """Pad/trim ``axis`` (negative index) of a to ``target`` length."""
        cur = a.shape[axis]
        if cur >= target:
            sl = [slice(None)] * a.ndim
            sl[axis] = slice(0, target)
            return a[tuple(sl)].astype(cache_dtype)
        cfg_pad = [(0, 0)] * a.ndim
        cfg_pad[a.ndim + axis] = (0, target - cur)
        return jnp.pad(a, cfg_pad).astype(cache_dtype)

    def conv(c):
        if isinstance(c, A.KVCache):
            return A.KVCache(_pad_seq(c.k, -3, s_max),
                             _pad_seq(c.v, -3, s_max))
        return c

    # window-attention archs need ring-buffer conversion; leading stacked
    # layer dims are folded into the batch dim first
    def conv_window(c, w):
        def fold(a):
            lead = a.shape[:-3]
            return a.reshape((-1,) + a.shape[-3:]), lead

        ks, lead = fold(c.k)
        vs, _ = fold(c.v)
        s = ks.shape[1]
        take = min(w, s, pos)
        lo = max(pos - take, 0)
        slots = (jnp.arange(lo, pos)) % w
        zk = jnp.zeros((ks.shape[0], w) + ks.shape[2:], cache_dtype)
        zv = jnp.zeros_like(zk)
        pos_slots = jnp.full((w,), -1, jnp.int32)
        zk = zk.at[:, slots].set(ks[:, lo:pos].astype(cache_dtype))
        zv = zv.at[:, slots].set(vs[:, lo:pos].astype(cache_dtype))
        pos_slots = pos_slots.at[slots].set(
            jnp.arange(lo, pos, dtype=jnp.int32))
        zk = zk.reshape(lead + zk.shape[1:])
        zv = zv.reshape(lead + zv.shape[1:])
        if len(lead) >= 2:      # scan-stacked groups carry (G, W) slots
            pos_slots = jnp.broadcast_to(pos_slots, (lead[0], w)).copy()
        return A.WindowKVCache(zk, zv, pos_slots)

    def walk(c):
        if isinstance(c, dict):
            out = {}
            for key, v in c.items():
                if key == "self" and isinstance(v, A.KVCache) \
                        and cfg.local_window:
                    out[key] = conv_window(v, cfg.local_window)
                elif isinstance(v, (A.KVCache, A.MLACache)):
                    out[key] = conv(v) if isinstance(v, A.KVCache) else \
                        _conv_mla(v, s_max, cache_dtype)
                else:
                    out[key] = v
            return out
        if isinstance(c, list):
            return [walk(x) for x in c]
        return c

    def _conv_mla(c, s_max, dt):
        return A.MLACache(_pad_seq(c.c_kv, -2, s_max),
                          _pad_seq(c.k_rope, -2, s_max))

    caches = jax.tree.map(lambda x: x, prefill_state.caches)  # copy struct
    caches = {"groups": [walk(g) for g in prefill_state.caches["groups"]],
              "rem": [walk(r) for r in prefill_state.caches["rem"]]}
    return M.DecodeState(caches, prefill_state.pos)


def main_overlay(argv=None):
    """Run a QueryServer over warm overlay engines and drive it with a
    closed-loop client pool; prints and returns the serving metrics."""
    import threading

    import numpy as np

    ap = argparse.ArgumentParser(prog="serve overlay")
    ap.add_argument("--topology", default="ba",
                    help="comma list of registered topology families "
                         "(one warm engine per entry)")
    ap.add_argument("--n-peers", type=int, default=1000)
    ap.add_argument("--backend", default="numpy",
                    choices=("numpy", "jax"))
    ap.add_argument("--policies", default="fd-dynamic,cn",
                    help="comma list of engine policy names, assigned "
                         "round-robin to requests")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--concurrency", type=int, default=16,
                    help="closed-loop client threads")
    ap.add_argument("--n-trials", type=int, default=1)
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-queue", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--batch-window-ms", type=float, default=2.0)
    ap.add_argument("--timeout-s", type=float, default=None)
    args = ap.parse_args(argv)

    from repro.engine import QueryServer, QuerySpec, ServerConfig, SimEngine
    from repro.engine.serve import ServerError
    from repro.p2psim import SimParams, build_topology

    params = SimParams(k=args.k)
    engines = {}
    for fam in args.topology.split(","):
        fam = fam.strip()
        topo = build_topology(fam, args.n_peers, seed=args.seed)
        engines[fam] = SimEngine(topo, params=params,
                                 backend=args.backend)
    policies = [p.strip() for p in args.policies.split(",")]
    names = sorted(engines)
    server = QueryServer(engines, ServerConfig(
        max_queue=args.max_queue, max_batch=args.max_batch,
        batch_window_s=args.batch_window_ms / 1e3,
        default_timeout_s=args.timeout_s))
    for name in names:      # populate plan / jit caches before load
        server.warm(QuerySpec(origins=(0,), seed=args.seed),
                    policies[0], engine=name)

    rng = np.random.default_rng(args.seed)
    reqs = [(QuerySpec(origins=(int(rng.integers(args.n_peers)),),
                       n_trials=args.n_trials,
                       seed=int(rng.integers(1 << 30))),
             policies[i % len(policies)], names[i % len(names)])
            for i in range(args.requests)]
    cursor = {"i": 0}
    lock = threading.Lock()
    errors = []

    def client():
        while True:
            with lock:
                i = cursor["i"]
                if i >= len(reqs):
                    return
                cursor["i"] = i + 1
            spec, pol, name = reqs[i]
            try:
                server.query(spec, pol, engine=name)
            except ServerError as e:     # shed/timeout: counted, not fatal
                errors.append(e)

    with server:
        t0 = time.perf_counter()
        threads = [threading.Thread(target=client)
                   for _ in range(args.concurrency)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        m = server.metrics()
    qps = m.served / max(wall, 1e-9)
    print(f"served {m.served}/{args.requests} requests over "
          f"{len(engines)} engine(s) [{args.backend}] in {wall:.2f}s "
          f"({qps:.1f} qps); shed {m.shed}, timed out {m.timed_out}")
    if m.latency is not None:
        print("latency p50/p95/p99 = "
              f"{m.latency.p50_s * 1e3:.2f}/{m.latency.p95_s * 1e3:.2f}/"
              f"{m.latency.p99_s * 1e3:.2f} ms; mean batch "
              f"{m.mean_batch:.2f} (max {m.max_batch})")
    metrics = m.as_dict()
    metrics["wall_s"] = wall
    metrics["throughput_qps"] = qps
    return metrics


def main_decode(argv=None):
    """LM prefill + decode driver (FD top-k sampling each step)."""
    ap = argparse.ArgumentParser(prog="serve decode")
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--policy", default=None,
                    help="engine policy name (fd-dynamic / cn / cn-star; "
                         "see repro.engine); overrides --algorithm")
    ap.add_argument("--algorithm", default="fd",
                    choices=("fd", "cn", "cn_star"),
                    help="legacy algorithm flag (mapped onto a policy)")
    ap.add_argument("--schedule", default="halving",
                    choices=("halving", "doubling", "ring"))
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding

    from repro import jaxcompat
    from repro.configs.base import get_config, smoke_config
    from repro.data.pipeline import extra_model_inputs
    from repro.engine import get_policy, policy_from_legacy
    from repro.launch.mesh import make_host_mesh
    from repro.models import model as M
    from repro.optim.sharding import batch_axes, param_specs
    from repro.runtime.steps import make_serve_step

    try:
        pol = (get_policy(args.policy) if args.policy
               else policy_from_legacy(args.algorithm))
    except KeyError as e:
        raise SystemExit(f"--policy: {e.args[0]}")
    if pol.algorithm not in ("fd", "cn", "cn_star"):
        raise SystemExit(f"policy {pol.name!r} has no device backend")

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    mesh = make_host_mesh(model=args.model_par)
    ctx = jaxcompat.use_mesh(mesh)
    ctx.__enter__()
    s_max = args.prompt_len + args.gen

    key = jax.random.PRNGKey(0)
    params_abs = jax.eval_shape(
        lambda k: M.init_params(k, cfg, max_seq=s_max), key)
    pspecs = param_specs(params_abs, cfg, mesh)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    params = jax.jit(lambda k: M.init_params(k, cfg, max_seq=s_max),
                     out_shardings=pshard)(key)

    rng = np.random.default_rng(0)
    batch_np = {"tokens": rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)}
    batch = extra_model_inputs(cfg, batch_np)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}

    t0 = time.time()
    last_logits, pstate = M.prefill(params, cfg, batch)
    state = state_from_prefill(cfg, pstate, s_max)
    t_prefill = time.time() - t0

    baxes = batch_axes(dict(mesh.shape))
    serve_step = jax.jit(
        make_serve_step(cfg, mesh, k=args.k, algorithm=pol.algorithm,
                        schedule=args.schedule, batch_axes=baxes),
        donate_argnums=(1,))

    tok = jnp.argmax(last_logits, axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    key = jax.random.PRNGKey(1)
    t0 = time.time()
    for i in range(args.gen - 1):
        key, sub = jax.random.split(key)
        tok, state = serve_step(params, state, tok, sub)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    toks = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print(f"arch={cfg.name} policy={pol.name} "
          f"prefill {args.prompt_len} tok in {t_prefill:.2f}s; "
          f"decoded {args.gen - 1} steps in {t_decode:.2f}s "
          f"({(args.gen - 1) * args.batch / max(t_decode, 1e-9):.1f} tok/s)")
    print("sample tokens:", toks[0, :12].tolist())
    ctx.__exit__(None, None, None)
    return toks


def main(argv=None):
    """Dispatch ``overlay`` / ``decode``; bare flags route to decode."""
    import sys
    argv = list(sys.argv[1:]) if argv is None else list(argv)
    if argv and argv[0] == "overlay":
        return main_overlay(argv[1:])
    if argv and argv[0] == "decode":
        return main_decode(argv[1:])
    return main_decode(argv)            # legacy flag-style invocation


if __name__ == "__main__":
    main()
