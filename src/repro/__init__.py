"""repro — FD (fully-distributed top-k) TPU framework."""
__version__ = "0.1.0"
