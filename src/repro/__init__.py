"""repro — FD (fully-distributed top-k) TPU framework."""
__version__ = "0.1.0"

# Unified engine surface (ISSUE 2): one import path for the query API.
# Resolved lazily so ``import repro`` stays dependency-free (DeviceEngine
# pulls in JAX, SimEngine pulls in the numpy simulator).
_ENGINE_EXPORTS = ("QuerySpec", "Policy", "TopKResult", "NetworkPlan",
                   "Engine", "SimEngine", "DeviceEngine", "QueryServer",
                   "ServerConfig", "get_policy", "register_policy",
                   "available_policies", "policy_from_legacy")

__all__ = list(_ENGINE_EXPORTS)


def __getattr__(name):
    if name in _ENGINE_EXPORTS:
        import repro.engine as _engine
        return getattr(_engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
