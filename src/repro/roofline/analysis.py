"""Roofline terms from a compiled dry-run artifact.

    compute   = HLO_FLOPs / peak_FLOPs            (per chip — the SPMD
    memory    = HLO_bytes / HBM_bw                  module is per-device)
    collective= collective_bytes / link_bw

``collective_bytes`` is not in cost_analysis: we parse the post-SPMD HLO
text and sum the operand bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute instruction (shapes in
the per-device module are shard shapes, so the result is bytes crossing
this chip's links).

Hardware constants: TPU v5e-ish — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (4 links/chip on a 2D torus; we charge the serialized
per-chip byte stream against one link, the conservative bound).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12       # bf16 per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    link_bw: float = 50e9            # bytes/s per ICI link
    dcn_bw: float = 6.25e9           # bytes/s per host NIC (multi-pod axis)


DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
# instruction definition: "  %name = <shape-or-tuple> opcode(...)"
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")


def _shape_bytes(text: str) -> int:
    """Sum bytes over every dtype[dims] group in ``text`` (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str,
                              per_op: bool = False):
    """Sum operand bytes of collective ops in (post-SPMD, per-device) HLO.

    Operand shapes are read from each instruction's own operand list —
    HLO text includes typed operands, e.g.
      %ag = f32[512,128] all-gather(f32[32,128] %p), replica_groups=...
    For start/done pairs (async collectives) only the -start is counted.
    """
    totals: Dict[str, int] = {op: 0 for op in _COLL_OPS}
    counts: Dict[str, int] = {op: 0 for op in _COLL_OPS}
    name_shape: Dict[str, str] = {}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        name, rhs = m.groups()
        # record def shape (text up to the opcode) for operand lookup
        paren = rhs.find("(")
        head = rhs[:paren] if paren > 0 else rhs
        name_shape[name] = head
    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        name, rhs = m.groups()
        opm = re.search(r"\b(" + "|".join(_COLL_OPS) + r")(-start)?\(", rhs)
        if not opm:
            continue
        if re.search(r"\b(all-gather|all-reduce|all-to-all|"
                     r"reduce-scatter|collective-permute)-done\b", rhs):
            continue
        op = opm.group(1)
        # operand section: inside the first (...) after the opcode
        start = rhs.find("(", opm.start())
        depth, end = 0, start
        for i in range(start, len(rhs)):
            if rhs[i] == "(":
                depth += 1
            elif rhs[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = rhs[start + 1:end]
        b = _shape_bytes(operands)
        if b == 0:
            # untyped operands (%ref only): look up definitions
            for ref in re.findall(r"%([\w.\-]+)", operands):
                b += _shape_bytes(name_shape.get(ref, ""))
        totals[op] += b
        counts[op] += 1
    out = {"total": sum(totals.values()), "by_op": totals,
           "counts": counts}
    return out if per_op else out["total"]


def roofline_terms(*, hlo_flops: float, hlo_bytes: float,
                   collective_bytes: float, hw: HW = HW(),
                   model_flops: Optional[float] = None,
                   chips: int = 1) -> dict:
    """Three terms in seconds (per-device module convention) + verdict."""
    compute_s = hlo_flops / hw.peak_flops
    memory_s = hlo_bytes / hw.hbm_bw
    coll_s = collective_bytes / hw.link_bw
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    out = {**terms, "dominant": dominant, "bound_s": bound, "chips": chips}
    if model_flops is not None and hlo_flops:
        out["model_flops"] = model_flops
        out["useful_flops_ratio"] = model_flops / (hlo_flops * chips)
        # roofline fraction: useful model FLOPs per chip over what the
        # dominant term allows
        out["roofline_frac"] = (model_flops / chips / hw.peak_flops) / bound
    return out


def model_flops_estimate(cfg, shape, *, mode: str) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); decode: D=B tokens."""
    n_active = cfg.param_count(active_only=True)
    if mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence per step
    return 2.0 * n_active * shape.global_batch
