"""Render the dry-run artifacts into the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m repro.roofline.report artifacts/dryrun
"""
from __future__ import annotations

import json
import os
import sys


def fmt_s(x):
    return f"{x:.3e}" if x else "0"


def load(art_dir: str):
    recs = []
    for name in sorted(os.listdir(art_dir)):
        if name.endswith(".json"):
            with open(os.path.join(art_dir, name)) as f:
                recs.append(json.load(f))
    return recs


def dryrun_table(recs, mesh: str):
    rows = ["| arch | shape | kind | compile(s) | GiB/dev | mb | "
            "coll GB/dev | collective mix |",
            "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("mesh") != mesh or r.get("skipped") or r.get("error"):
            continue
        coll = r.get("collective", {})
        mix = coll.get("by_op", {})
        top = sorted(mix.items(), key=lambda kv: -kv[1])[:2]
        mixs = " ".join(f"{k}:{v / 1e9:.2f}G" for k, v in top if v)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {r.get('t_compile_s', '?')} "
            f"| {r.get('memory', {}).get('per_device_total_gib', '?')} "
            f"| {r.get('microbatches', '-')} "
            f"| {coll.get('total', 0) / 1e9:.3f} | {mixs} |")
    skipped = [r for r in recs if r.get("mesh") == mesh and r.get("skipped")]
    for r in skipped:
        rows.append(f"| {r['arch']} | {r['shape']} | — | skipped "
                    "(structural) | | | | |")
    return "\n".join(rows)


def roofline_table(recs, mesh: str):
    rows = ["| arch | shape | compute(s) | memory(s) | collective(s) | "
            "dominant | useful-FLOP ratio | roofline |",
            "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("mesh") != mesh or r.get("skipped") or r.get("error"):
            continue
        t = r.get("roofline", {})
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t.get('compute_s', 0))} "
            f"| {fmt_s(t.get('memory_s', 0))} "
            f"| {fmt_s(t.get('collective_s', 0))} "
            f"| {t.get('dominant', '?').replace('_s', '')} "
            f"| {t.get('useful_flops_ratio', 0):.3f} "
            f"| {t.get('roofline_frac', 0):.2%} |")
    return "\n".join(rows)


def summary(recs):
    ok = [r for r in recs if not r.get("skipped") and not r.get("error")]
    sk = [r for r in recs if r.get("skipped")]
    er = [r for r in recs if r.get("error")]
    doms = {}
    for r in ok:
        d = r.get("roofline", {}).get("dominant", "?")
        doms[d] = doms.get(d, 0) + 1
    return (f"{len(ok)} compiled, {len(sk)} skipped (structural), "
            f"{len(er)} failed; dominant terms: {doms}")


def main():
    art_dir = sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun"
    recs = load(art_dir)
    print("## Summary\n")
    print(summary(recs))
    for mesh in ("16x16", "2x16x16"):
        print(f"\n## Dry-run — mesh {mesh}\n")
        print(dryrun_table(recs, mesh))
        print(f"\n## Roofline — mesh {mesh}\n")
        print(roofline_table(recs, mesh))


if __name__ == "__main__":
    main()
