"""HLO text analyzer with while-loop trip-count weighting.

``compiled.cost_analysis()`` counts every computation ONCE — a scanned
80-layer stack reports 1/80th of the real FLOPs (verified empirically:
a scan of 4 dots reports exactly one dot's flops).  The roofline needs
executed totals, so we parse the post-SPMD HLO text ourselves:

  * computations are parsed into instruction lists with a name->shape map
    (operands are referenced by name in compiled HLO),
  * ``while`` ops multiply their body/condition by the trip count
    recovered from the condition computation's integer ``constant(N)``
    (scan lowering: induction from 0, step 1, compare LT),
  * dot FLOPs = 2 * prod(output dims) * prod(lhs contracting dims),
  * bytes = operand + output bytes at fusion boundaries (fusion
    internals live in registers — matches XLA's HBM-traffic view),
  * collective bytes = operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute; shapes in the
    per-device SPMD module are shard shapes, so totals are per-chip.

Everything is derived from the executable artifact, not the source
model — remat recompute, SPMD-inserted collectives and padding waste are
all visible.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_INST = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "copy-start", "copy-done", "after-all"}


def _parse_dims(dims: str) -> List[int]:
    return [int(d) for d in dims.split(",")] if dims else []


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in _parse_dims(dims):
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    out_text: str
    opcode: str
    operands_text: str
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: List[Instr]
    shapes: Dict[str, str]     # instr name -> result type text


_RESULT_RE = re.compile(r"^[a-z0-9]+\[[0-9,]*\](?:\{[^{}]*\})?")
_OPCODE_RE = re.compile(r"^([\w\-]+)\(")


def _split_instr(rhs: str):
    """rhs: '<result-type> opcode(<operands>), attrs...'.

    The result type is either 'dtype[dims]{layout}' or a parenthesised
    tuple of such (while/rng-bit-generator/...).
    """
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        out_text = rhs[:end + 1]
        rest = rhs[end + 1:].strip()
    else:
        m = _RESULT_RE.match(rhs)
        if not m:
            return None
        out_text = m.group(0)
        rest = rhs[m.end():].strip()
    mo = _OPCODE_RE.match(rest)
    if not mo:
        return None
    opcode = mo.group(1)
    start = mo.end() - 1
    depth = 0
    end = start
    for k2 in range(start, len(rest)):
        if rest[k2] == "(":
            depth += 1
        elif rest[k2] == ")":
            depth -= 1
            if depth == 0:
                end = k2
                break
    return out_text, opcode, rest[start + 1:end], rest[end + 1:]


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            s = line.strip()
            if s.endswith("{") and "->" in s and ("(" in s):
                is_entry = s.startswith("ENTRY")
                if is_entry:
                    s = s[len("ENTRY"):].strip()
                name = s.split("(", 1)[0].strip().lstrip("%").strip()
                if name:
                    cur = Computation(name, is_entry, [], {})
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            if cur.is_entry:
                comps["__entry__"] = cur
            cur = None
            continue
        m = _INST.match(line)
        if not m:
            continue
        iname, rhs = m.groups()
        sp = _split_instr(rhs)
        if sp is None:
            continue
        out_text, opcode, operands, attrs = sp
        cur.instrs.append(Instr(iname, out_text, opcode, operands, attrs))
        cur.shapes[iname] = out_text
    return comps


def _operand_bytes(inst: Instr, shapes: Dict[str, str]) -> int:
    b = _shape_bytes(inst.operands_text)
    if b:
        return b
    total = 0
    for ref in re.findall(r"%([\w.\-]+)", inst.operands_text):
        total += _shape_bytes(shapes.get(ref, ""))
    return total


def _first_operand_shape(inst: Instr, shapes: Dict[str, str]) -> List[int]:
    m = _SHAPE_RE.search(inst.operands_text)
    if m and m.group(1) in DTYPE_BYTES:
        return _parse_dims(m.group(2))
    refs = re.findall(r"%([\w.\-]+)", inst.operands_text)
    if refs:
        mm = _SHAPE_RE.search(shapes.get(refs[0], ""))
        if mm:
            return _parse_dims(mm.group(2))
    return []


def _out_elems(inst: Instr) -> int:
    m = _SHAPE_RE.search(inst.out_text)
    if not m or m.group(1) not in DTYPE_BYTES:
        return 0
    n = 1
    for d in _parse_dims(m.group(2)):
        n *= d
    return n


def _dot_flops(inst: Instr, shapes: Dict[str, str]) -> float:
    out_elems = _out_elems(inst)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.attrs)
    cdims = _parse_dims(m.group(1)) if m else []
    lhs_dims = _first_operand_shape(inst, shapes)
    csize = 1
    for c in cdims:
        if c < len(lhs_dims):
            csize *= lhs_dims[c]
    return 2.0 * out_elems * csize


def _conv_flops(inst: Instr, shapes: Dict[str, str]) -> float:
    out_elems = _out_elems(inst)
    refs = re.findall(r"%([\w.\-]+)", inst.operands_text)
    ker = 1
    if len(refs) >= 2:
        mm = _SHAPE_RE.search(shapes.get(refs[1], ""))
        if mm:
            kd = _parse_dims(mm.group(2))
            for d in kd[:-1]:
                ker *= d
    return 2.0 * out_elems * ker


def _while_trip_count(comps, cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts = []

    def scan_comp(c):
        for inst in c.instrs:
            if inst.opcode == "constant" and \
                    inst.out_text.split("[")[0] in ("s32", "u32", "s64",
                                                    "u64"):
                mm = re.search(r"(\d+)", inst.operands_text)
                if mm:
                    consts.append(int(mm.group(1)))
            elif inst.opcode == "fusion":
                mcall = re.search(r"calls=%?([\w.\-]+)", inst.attrs)
                if mcall and mcall.group(1) in comps:
                    scan_comp(comps[mcall.group(1)])

    scan_comp(cond)
    return max(consts) if consts else 1


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    convert_bytes: float = 0.0   # CPU float-normalization artifacts: XLA:CPU
    # has no native bf16, so it wraps bf16 ops in convert pairs (observed:
    # the whole stacked KV cache converted per layer).  These do not exist
    # on the TPU target, so they are tracked separately and EXCLUDED from
    # bytes_accessed; EXPERIMENTS.md reports both.
    coll_by_op: dict = dataclasses.field(default_factory=dict)
    coll_counts: dict = dataclasses.field(default_factory=dict)
    trip_counts: dict = dataclasses.field(default_factory=dict)


def analyze(text: str) -> Totals:
    comps = parse_hlo(text)
    tot = Totals(coll_by_op={o: 0.0 for o in _COLL_OPS},
                 coll_counts={o: 0 for o in _COLL_OPS})
    if "__entry__" not in comps:
        return tot
    fusion_flops_cache: Dict[str, float] = {}

    def fusion_flops(comp_name: str) -> float:
        if comp_name in fusion_flops_cache:
            return fusion_flops_cache[comp_name]
        c = comps.get(comp_name)
        f = 0.0
        if c is not None:
            for inst in c.instrs:
                if inst.opcode == "dot":
                    f += _dot_flops(inst, c.shapes)
                elif inst.opcode == "convolution":
                    f += _conv_flops(inst, c.shapes)
                elif inst.opcode == "fusion":
                    mcall = re.search(r"calls=%?([\w.\-]+)", inst.attrs)
                    if mcall:
                        f += fusion_flops(mcall.group(1))
        fusion_flops_cache[comp_name] = f
        return f

    fusion_traffic_cache: Dict[str, float] = {}

    def fusion_traffic(comp_name: str) -> Optional[float]:
        """Effective HBM traffic of a fusion: parameters consumed only by
        dynamic-slice are charged at slice size (scan xs reads), and a
        dynamic-update-slice root aliases its buffer in place (scan ys
        writes) — charging the full stacked buffer per layer iteration
        would overstate traffic by the layer count."""
        if comp_name in fusion_traffic_cache:
            return fusion_traffic_cache[comp_name]
        c = comps.get(comp_name)
        if c is None:
            return None
        total = 0.0
        uses: Dict[str, List[Instr]] = {}
        for inst in c.instrs:
            for ref in re.findall(r"%([\w.\-]+)", inst.operands_text):
                uses.setdefault(ref, []).append(inst)
        root = c.instrs[-1] if c.instrs else None
        dus_alias = None
        if root is not None and root.opcode == "dynamic-update-slice":
            refs = re.findall(r"%([\w.\-]+)", root.operands_text)
            if refs:
                dus_alias = refs[0]           # the aliased big buffer
                upd = refs[1] if len(refs) > 1 else None
                total += 2 * _shape_bytes(c.shapes.get(upd, "")) \
                    if upd else 0             # read+write of the slice
        else:
            total += _shape_bytes(root.out_text) if root else 0
        for inst in c.instrs:
            if inst.opcode != "parameter":
                continue
            if inst.name == dus_alias:
                continue                      # in-place alias: free
            u = uses.get(inst.name, [])
            if u and all(x.opcode in ("dynamic-slice", "bitcast")
                         for x in u):
                total += sum(_shape_bytes(x.out_text) for x in u
                             if x.opcode == "dynamic-slice")
            else:
                total += _shape_bytes(inst.out_text)
        fusion_traffic_cache[comp_name] = total
        return total

    def walk(comp_name: str, mult: float, depth: int = 0):
        c = comps.get(comp_name)
        if c is None or mult == 0 or depth > 64:
            return
        for inst in c.instrs:
            op = inst.opcode
            if op == "dot":
                tot.flops += mult * _dot_flops(inst, c.shapes)
                tot.bytes_accessed += mult * (
                    _shape_bytes(inst.out_text)
                    + _operand_bytes(inst, c.shapes))
            elif op == "convolution":
                tot.flops += mult * _conv_flops(inst, c.shapes)
                tot.bytes_accessed += mult * (
                    _shape_bytes(inst.out_text)
                    + _operand_bytes(inst, c.shapes))
            elif op == "fusion":
                mcall = re.search(r"calls=%?([\w.\-]+)", inst.attrs)
                b = None
                if mcall:
                    tot.flops += mult * fusion_flops(mcall.group(1))
                    b = fusion_traffic(mcall.group(1))
                if b is None:
                    b = (_shape_bytes(inst.out_text)
                         + _operand_bytes(inst, c.shapes))
                if inst.name.startswith("wrapped_convert") or (
                        mcall and "convert_computation" in mcall.group(1)):
                    tot.convert_bytes += mult * b
                else:
                    tot.bytes_accessed += mult * b
            elif op == "while":
                mc = re.search(r"condition=%?([\w.\-]+)", inst.attrs)
                mb = re.search(r"body=%?([\w.\-]+)", inst.attrs)
                trip = _while_trip_count(comps, mc.group(1)) if mc else 1
                tot.trip_counts[f"{inst.name}@{comp_name}"] = trip
                if mb:
                    walk(mb.group(1), mult * trip, depth + 1)
            elif op in ("call", "custom-call", "conditional"):
                for mcall in re.finditer(
                        r"(?:to_apply|calls|branch_computations)="
                        r"(%?[\w.\-]+|\{[^}]*\})", inst.attrs):
                    blob = mcall.group(1)
                    for ref in re.findall(r"%?([\w.\-]+)", blob):
                        if ref in comps:
                            walk(ref, mult, depth + 1)
                tot.bytes_accessed += mult * (
                    _shape_bytes(inst.out_text)
                    + _operand_bytes(inst, c.shapes))
            else:
                base = op[:-6] if op.endswith("-start") else op
                if base in _COLL_OPS and not op.endswith("-done"):
                    b = _operand_bytes(inst, c.shapes)
                    tot.collective_bytes += mult * b
                    tot.coll_by_op[base] += mult * b
                    tot.coll_counts[base] += int(mult)
                    tot.bytes_accessed += mult * (
                        _shape_bytes(inst.out_text) + b)
                elif op in _SKIP_BYTES_OPS or op.endswith("-done"):
                    pass
                elif op == "dynamic-slice":
                    tot.bytes_accessed += mult * 2 * _shape_bytes(
                        inst.out_text)
                elif op == "dynamic-update-slice":
                    refs = re.findall(r"%([\w.\-]+)", inst.operands_text)
                    upd = c.shapes.get(refs[1], "") if len(refs) > 1 else \
                        inst.out_text
                    tot.bytes_accessed += mult * 2 * _shape_bytes(upd)
                elif op == "convert":
                    tot.convert_bytes += mult * (
                        _shape_bytes(inst.out_text)
                        + _operand_bytes(inst, c.shapes))
                else:
                    tot.bytes_accessed += mult * (
                        _shape_bytes(inst.out_text)
                        + _operand_bytes(inst, c.shapes))

    walk("__entry__", 1.0)
    return tot
