from repro.runtime.ft import (  # noqa: F401
    FailureInjector, StragglerWatchdog, run_with_recovery)
