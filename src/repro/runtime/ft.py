"""Fault tolerance at the driver level — the TPU-idiomatic form of the
paper's §4 dynamicity handling.

Mapping from the paper:
  * wait-time cost model (Appendix A) -> ``StragglerWatchdog``: a step that
    exceeds ``timeout_fn(step_params)`` is declared a straggler, exactly
    the peer whose score-list misses the wait window.
  * urgent score-lists / alternative paths -> ``run_with_recovery``: work
    lost to a failure is NOT discarded; the driver restores the latest
    checkpoint and requeues the remaining steps (the information still
    reaches the "originator", late).
  * k-inflation (Lemma 4) -> over-provisioning hooks: the recovery driver
    accepts ``spare_fraction`` so a deployment reserves hot spares, and
    compress.inflate_k applies the same lemma to gradient k-lists.

On a real multi-pod deployment the watchdog wraps the per-step
``jax.block_until_ready`` at the coordinator; failures surface as jax
RuntimeErrors which the recovery loop catches.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Optional


# --------------------------------------------------------------------------
# failure model (for tests / simulation; exponential lifetimes as in the
# paper's §5.4 churn study)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class FailureInjector:
    """Deterministic-seeded exponential failure process."""
    mtbf_steps: float = float("inf")
    seed: int = 0
    _step: int = 0

    def tick(self) -> bool:
        """Advance one step; True -> inject a failure now."""
        import numpy as np
        self._step += 1
        if self.mtbf_steps == float("inf"):
            return False
        rng = np.random.default_rng((self.seed, self._step))
        return bool(rng.random() < 1.0 / self.mtbf_steps)


class SimulatedFailure(RuntimeError):
    pass


# --------------------------------------------------------------------------
# straggler watchdog (Appendix A wait time -> step timeout)
# --------------------------------------------------------------------------

class StragglerTimeout(RuntimeError):
    pass


class StragglerWatchdog:
    """Run a callable with a wall-clock budget.

    ``timeout_s`` plays the paper's Wait_p(Q, ttl) role: generous enough
    not to cut off healthy peers, tight enough to catch dead ones.  The
    default budget auto-calibrates to ``factor`` x the rolling median
    step time (network/load-adaptive, like the paper's statistics-based
    estimation of T_Qsnd / T_SLsnd).
    """

    def __init__(self, *, timeout_s: Optional[float] = None,
                 factor: float = 5.0, min_timeout_s: float = 1.0):
        self.timeout_s = timeout_s
        self.factor = factor
        self.min_timeout_s = min_timeout_s
        self._times: list = []

    def budget(self) -> float:
        if self.timeout_s is not None:
            return self.timeout_s
        if not self._times:
            return float("inf")
        med = sorted(self._times)[len(self._times) // 2]
        return max(self.min_timeout_s, self.factor * med)

    def run(self, fn: Callable[[], Any]) -> Any:
        budget = self.budget()
        result: dict = {}

        def target():
            try:
                result["value"] = fn()
            except BaseException as e:      # noqa: BLE001
                result["error"] = e

        t0 = time.monotonic()
        th = threading.Thread(target=target, daemon=True)
        th.start()
        th.join(timeout=None if budget == float("inf") else budget)
        if th.is_alive():
            raise StragglerTimeout(
                f"step exceeded {budget:.2f}s watchdog budget")
        if "error" in result:
            raise result["error"]
        self._times.append(time.monotonic() - t0)
        if len(self._times) > 64:
            self._times.pop(0)
        return result["value"]


# --------------------------------------------------------------------------
# recovery driver
# --------------------------------------------------------------------------

def run_with_recovery(step_fn: Callable[[int, Any], Any], state: Any,
                      *, n_steps: int, ckpt_manager=None,
                      restore_fn: Optional[Callable[[], Any]] = None,
                      watchdog: Optional[StragglerWatchdog] = None,
                      max_failures: int = 8,
                      on_failure: Optional[Callable[[int, Exception], None]]
                      = None,
                      start_step: int = 0) -> Any:
    """Run ``state = step_fn(step, state)`` for n_steps with checkpoint/
    restart.  On failure: restore the latest checkpoint (or ``restore_fn``)
    and requeue from there.  Returns the final state.
    """
    failures = 0
    step = start_step
    while step < n_steps:
        try:
            if watchdog is not None:
                state = watchdog.run(lambda: step_fn(step, state))
            else:
                state = step_fn(step, state)
            if ckpt_manager is not None:
                ckpt_manager.maybe_save(step + 1, state)
            step += 1
        except Exception as e:              # noqa: BLE001
            failures += 1
            if on_failure is not None:
                on_failure(step, e)
            if failures > max_failures:
                raise
            if restore_fn is not None:
                restored = restore_fn()
                if restored is not None:
                    restored_step, restored_state = restored
                    if restored_state is not None:
                        step, state = restored_step, restored_state
            # else: retry the same step with the in-memory state
    if ckpt_manager is not None:
        ckpt_manager.maybe_save(step, state, force=True)
        ckpt_manager.wait()
    return state
