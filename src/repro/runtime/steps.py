"""jit-able step builders shared by the drivers and the dry-run.

  * ``make_train_step``  — grads (with microbatch accumulation) + AdamW
  * ``make_prefill_step``— prompt -> (last logits, DecodeState)
  * ``make_serve_step``  — one decode token + FD top-k sampling over the
                           vocab-sharded logits (the paper's technique as
                           a first-class serving feature)

All functions are pure; sharding is injected by the caller via
in_shardings/out_shardings (see launch/dryrun.py and launch/train.py).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import fd
from repro.models import model as M
from repro.optim.adamw import AdamWConfig, adamw_update


# --------------------------------------------------------------------------
# train
# --------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    microbatches: int = 1, remat: str = "full",
                    batch_axes=("data",), q_block: int = 1024,
                    kv_block: int = 1024, acc_specs=None):
    """Returns train_step(params, opt_state, batch) -> (params', opt', metrics).

    ``acc_specs``: optional PartitionSpec pytree for the f32 gradient
    accumulator (ZeRO-1: data-sharded accumulator turns per-microbatch
    gradient all-reduces into reduce-scatters).
    """

    def loss_of(p, mb):
        return M.loss_fn(p, cfg, mb, remat=remat, q_block=q_block,
                         kv_block=kv_block)

    def constrain_acc(g):
        if acc_specs is None:
            return g
        return jax.tree.map(
            lambda a, s: jax.lax.with_sharding_constraint(a, s),
            g, acc_specs)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, _), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
        else:
            def split(x):
                y = x.reshape((microbatches, x.shape[0] // microbatches)
                              + x.shape[1:])
                return jax.lax.with_sharding_constraint(
                    y, P(None, batch_axes, *([None] * (x.ndim - 1))))
            mbs = jax.tree.map(split, batch)

            def body(carry, mb):
                g_acc, l_acc = carry
                (mb_loss, _), g = jax.value_and_grad(
                    loss_of, has_aux=True)(params, mb)
                g_acc = constrain_acc(jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g))
                return (g_acc, l_acc + mb_loss), None

            g0 = constrain_acc(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (g_sum, l_sum), _ = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, g_sum)
            loss = l_sum / microbatches

        new_params, new_opt, om = adamw_update(grads, opt_state, params,
                                               opt_cfg)
        return new_params, new_opt, {"loss": loss, **om}

    return train_step


# --------------------------------------------------------------------------
# prefill
# --------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, *, q_block: int = 1024,
                      kv_block: int = 1024):
    def prefill_step(params, batch):
        return M.prefill(params, cfg, batch, q_block=q_block,
                         kv_block=kv_block)
    return prefill_step


# --------------------------------------------------------------------------
# serve (decode + FD sampling)
# --------------------------------------------------------------------------

def make_serve_step(cfg: ModelConfig, mesh, *, k: int = 20,
                    algorithm: str = "fd", schedule: str = "halving",
                    temperature: float = 1.0, batch_axes=("data",)):
    """serve_step(params, state, tokens, rng) -> (next_tokens, state').

    The vocabulary top-k is computed with the FD merge-and-backward over
    the ``model`` axis — O(k log TP) bytes per step instead of CN's O(V).
    ``algorithm`` selects fd / cn / cn_star for benchmarking.
    """
    msize = mesh.shape.get("model", 1) if hasattr(mesh.shape, "get") \
        else dict(mesh.shape)["model"]

    def serve_step(params, state, tokens, rng):
        logits, new_state = M.decode_step(params, cfg, state, tokens)
        scores = logits[:, 0].astype(jnp.float32)           # (B, V) sharded
        if msize > 1:
            vals, idx = fd.fd_topk(scores, k, mesh, "model",
                                   schedule=schedule, algorithm=algorithm,
                                   batch_axes=batch_axes)
        else:
            vals, idx = jax.lax.top_k(scores, k)
        # sample among the k winners (phase-4 retrieval touches only them)
        probs = jax.nn.softmax(vals / temperature, axis=-1)
        choice = jax.random.categorical(rng, jnp.log(probs + 1e-9), axis=-1)
        next_tok = jnp.take_along_axis(idx, choice[:, None], axis=-1)
        return next_tok.astype(jnp.int32), new_state

    return serve_step
