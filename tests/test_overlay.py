"""Live overlays: Overlay mutations, incremental NetworkPlan sync
(bit-exact vs a from-scratch rebuild, all backends, both RNG modes),
session dynamics, repair policies, and replication."""

import dataclasses

import numpy as np
import pytest

from repro.engine import (NetworkPlan, Overlay, QuerySpec, SimEngine,
                          get_policy, registry)
from repro.p2psim import SimParams, barabasi_albert, waxman
from repro.p2psim.graph import Topology, bfs_tree, eccentricity_ttl
from repro.p2psim.overlay import (SessionEvent, apply_events,
                                  random_session)
from repro.p2psim.simulate import run_query_reference

PA = SimParams(seed=11)
_FIELDS = ("m_fw", "m_bw", "m_rt", "b_bw", "b_rt", "response_time_s",
           "accuracy")


def _path_topology(n):
    nb = [np.array([v for v in (u - 1, u + 1) if 0 <= v < n], np.int32)
          for u in range(n)]
    return Topology(n=n, neighbors=nb, kind="path")


def _assert_plans_agree(synced_plan, top, origins, *, params=PA,
                        lifetime_mean_s=30.0, modes=("shared", "independent"),
                        latency_models=("iid",)):
    """Engine results off the synced plan == fresh-rebuild plan == the
    scalar reference, on the numpy AND jax backends, in every RNG mode."""
    pol = get_policy("fd-dynamic").variant(lifetime_mean_s=lifetime_mean_s)
    fresh = NetworkPlan(top)
    engines = [SimEngine(synced_plan, params),
               SimEngine(fresh, params),
               SimEngine(synced_plan, params, backend="jax"),
               SimEngine(fresh, params, backend="jax")]
    for lm in latency_models:
        for rng in modes:
            spec = QuerySpec(origins=tuple(origins), n_trials=2, rng=rng,
                             latency_model=lm)
            base = engines[0].run(spec, pol).metrics
            for eng in engines[1:]:
                got = eng.run(spec, pol).metrics
                for f in _FIELDS:
                    np.testing.assert_array_equal(
                        getattr(base, f), getattr(got, f),
                        err_msg=f"{f} ({rng}, {lm}, {eng.backend})")
    # scalar reference spot check (shared batch-of-1 == reference)
    o = int(origins[0])
    ref, _ = run_query_reference(top, o, params, dynamic=True,
                                 lifetime_mean_s=lifetime_mean_s)
    one = SimEngine(synced_plan, params).run(
        QuerySpec(origins=(o,)), pol)
    assert one.query_metrics(0, 0) == ref


# --------------------------------------------------------------------------
# Overlay mutation API
# --------------------------------------------------------------------------

def test_overlay_mutations_version_and_journal():
    top = barabasi_albert(40, m=2, seed=1)
    ov = Overlay(top)
    assert ov.version == 0 and ov.n == 40
    v0 = ov.version
    former = ov.remove_peer(7)
    assert ov.degree(7) == 0 and len(former) > 0
    assert ov.version > v0
    assert all(not ov.has_edge(7, int(v)) for v in former)
    pid = ov.add_peer(neighbors=(0, 3))
    assert pid == 40 and ov.n == 41
    assert ov.has_edge(pid, 0) and ov.has_edge(pid, 3)
    deltas = ov.deltas_since(v0)
    assert deltas[0].op == "remove_peer" and deltas[0].nodes[0] == 7
    assert [d.version for d in deltas] == sorted(
        d.version for d in deltas)
    # the wrapped topology was snapshotted: the caller's is untouched
    assert len(top.neighbors[7]) > 0 and top.n == 40
    # sorted-int32 adjacency invariant holds everywhere
    for a in ov.top.neighbors:
        assert a.dtype == np.int32 and (np.diff(a) > 0).all()


def test_overlay_rejects_invalid_mutations():
    ov = Overlay(barabasi_albert(20, m=2, seed=0))
    with pytest.raises(ValueError, match="self-loop"):
        ov.add_edge(3, 3)
    if not ov.has_edge(0, 19):
        ov.add_edge(0, 19)
    with pytest.raises(ValueError, match="already exists"):
        ov.add_edge(0, 19)
    absent = next(v for v in range(1, 20) if not ov.has_edge(0, v))
    with pytest.raises(ValueError, match="does not exist"):
        ov.remove_edge(0, absent)
    with pytest.raises(ValueError, match="out of range"):
        ov.add_edge(0, 99)
    with pytest.raises(ValueError, match="no coordinates"):
        ov.add_peer(neighbors=(0,), coords=(0.1, 0.2))


def test_add_peer_coords_on_embedded_topology():
    ov = Overlay(waxman(30, seed=2))
    pid = ov.add_peer(neighbors=(0, 1))
    np.testing.assert_allclose(ov.top.coords[pid],
                               ov.top.coords[[0, 1]].mean(axis=0))
    pid2 = ov.add_peer(neighbors=(2,), coords=(0.25, 0.75))
    np.testing.assert_array_equal(ov.top.coords[pid2], [0.25, 0.75])


# --------------------------------------------------------------------------
# incremental plan sync: edge cases, bit-exact vs rebuild
# --------------------------------------------------------------------------

def test_sync_noop_and_version_tracking():
    ov = Overlay(barabasi_albert(60, m=2, seed=3))
    plan = NetworkPlan(ov)
    assert plan.overlay is ov and plan.sync() is False
    ov.add_edge(0, 50) if not ov.has_edge(0, 50) else ov.remove_edge(0, 50)
    assert plan.sync() is True and plan.version == ov.version
    assert plan.sync() is False


def test_sync_cut_vertex_removal_splits_origin_component():
    # two BA blobs bridged through one cut vertex
    a = barabasi_albert(30, m=2, seed=4)
    nb = [x.copy() for x in a.neighbors]
    off = 30
    b = barabasi_albert(30, m=2, seed=5)
    nb += [(x + off).astype(np.int32) for x in b.neighbors]
    top = Topology(n=60, neighbors=[np.sort(x) for x in nb], kind="ba")
    ov = Overlay(top)
    ov.add_edge(0, 29)      # ensure 29 bridges: 29 <-> 0 and 29 <-> 30+
    ov.add_edge(29, 30 + 0)
    plan = NetworkPlan(ov)
    eng = SimEngine(plan, PA)
    eng.run(QuerySpec(origins=(0, 45)), "fd-st1+2")       # warm caches
    ov.remove_peer(29)                                    # the cut vertex
    plan.sync()
    _, _, reached = bfs_tree(ov.top, 0, ov.n)
    assert not reached[45]                  # origin component split
    _assert_plans_agree(plan, ov.top, (0, 45))


def test_sync_removing_the_origin_itself():
    ov = Overlay(barabasi_albert(50, m=2, seed=6))
    plan = NetworkPlan(ov)
    eng = SimEngine(plan, PA)
    eng.run(QuerySpec(origins=(13,)), "fd-dynamic")       # cache origin 13
    ov.remove_peer(13)
    plan.sync()
    # the tombstoned origin only ever reaches itself
    res = eng.run(QuerySpec(origins=(13,)), "fd-st1+2")
    assert res.metrics.n_reached[0, 0] == 1
    _assert_plans_agree(plan, ov.top, (13, 0))


def test_sync_join_shortens_eccentricity_auto_ttl_shrinks():
    ov = Overlay(_path_topology(10))
    plan = NetworkPlan(ov)
    assert plan.auto_ttl(0) == 9
    pid = ov.add_peer(neighbors=(0, 9))     # shortcut across the path
    plan.sync()
    assert plan.auto_ttl(0) == eccentricity_ttl(ov.top, 0) < 9
    assert plan.auto_ttl(pid) == eccentricity_ttl(ov.top, pid)
    _assert_plans_agree(plan, ov.top, (0, 5), lifetime_mean_s=float("inf"))


def test_sync_interleaved_fuzz_bit_exact_vs_rebuild():
    ov = Overlay(waxman(90, seed=7))
    plan = NetworkPlan(ov)
    eng = SimEngine(plan, PA)
    rng = np.random.default_rng(0)
    for round_ in range(4):
        eng.run(QuerySpec(origins=(0, 33, 70), n_trials=2), "fd-dynamic")
        events = random_session(ov, int(rng.integers(3, 9)),
                                seed=100 + round_, join_prob=0.5)
        apply_events(ov, events, repair="reconnect")
        assert plan.sync() is True
        _assert_plans_agree(plan, ov.top, (0, 33, 70),
                            latency_models=("iid", "edge"))


def test_sync_refreshes_edge_latency_tier():
    # an edge delta that does NOT move any cached BFS tree must still
    # refresh forward masks + edge_lat (the refresh_edges tier)
    ov = Overlay(waxman(60, seed=8))
    plan = NetworkPlan(ov)
    eng = SimEngine(plan, PA)
    eng.run(QuerySpec(origins=(0,), latency_model="edge"), "fd-st1+2")
    # add a non-tree edge between two peers already at equal depth
    _, depth, _ = bfs_tree(ov.top, 0, ov.n)
    cand = [(u, v) for u in range(ov.n) for v in range(u + 1, ov.n)
            if depth[u] == depth[v] and depth[u] >= 1
            and not ov.has_edge(u, v)]
    u, v = cand[0]
    ov.add_edge(u, v)
    plan.sync()
    _assert_plans_agree(plan, ov.top, (0,), lifetime_mean_s=float("inf"),
                        latency_models=("iid", "edge"))


def test_patch_tree_skips_bfs_and_matches_fresh_flood(monkeypatch):
    # a leaf leave + a join are rank-certified: sync must not re-flood
    # any cached tree, yet land bit-identical to a fresh plan's BFS
    import repro.engine.plan as planmod
    ov = Overlay(_path_topology(30))
    plan = NetworkPlan(ov)
    plan.origin_statics(np.asarray([3]), 0, "st1+2")

    def boom(*a, **k):
        raise AssertionError("sync re-flooded a rank-certified delta")

    monkeypatch.setattr(planmod, "bfs_tree_csr_multi", boom)
    ov.remove_peer(29)                       # tree leaf: childless rule
    plan.sync()
    pid = ov.add_peer(neighbors=(0,))        # join: bounded-depth rule
    plan.sync()
    monkeypatch.undo()
    (a,), _ = plan.origin_statics(np.asarray([3]), 0, "st1+2")
    (b,), _ = NetworkPlan(ov.top).origin_statics(np.asarray([3]), 0,
                                                 "st1+2")
    for f in ("parent", "depth", "rank", "idx", "ttl_rem", "kid_sorted",
              "kid_ptr", "ttl"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                      err_msg=f)
    assert a.depth[pid] == 4 and a.depth[29] == -1
    _assert_plans_agree(plan, ov.top, (3, pid),
                        lifetime_mean_s=float("inf"))


def test_patch_tree_bails_to_bfs_on_structural_shortcut():
    # a long-range shortcut re-parents a node WITH tree children — the
    # certificate cannot cover the cascade, so sync re-floods (and the
    # re-flood is still bit-exact vs a rebuild)
    import repro.engine.plan as planmod
    ov = Overlay(_path_topology(30))
    plan = NetworkPlan(ov)
    plan.origin_statics(np.asarray([3]), 0, "st1+2")
    calls = []
    real = planmod.bfs_tree_csr_multi

    def spy(*a, **k):
        calls.append(a)
        return real(*a, **k)

    planmod.bfs_tree_csr_multi = spy
    try:
        ov.add_edge(4, 20)                   # 20 keeps child 21: cascade
        plan.sync()
    finally:
        planmod.bfs_tree_csr_multi = real
    assert calls, "structural delta must fall back to the BFS sweep"
    _assert_plans_agree(plan, ov.top, (3,),
                        lifetime_mean_s=float("inf"))


# --------------------------------------------------------------------------
# session dynamics + repair policies
# --------------------------------------------------------------------------

def test_random_session_reproducible_and_consistent():
    ov1 = Overlay(barabasi_albert(40, m=2, seed=9))
    ov2 = Overlay(barabasi_albert(40, m=2, seed=9))
    ev1 = random_session(ov1, 20, seed=3)
    ev2 = random_session(ov2, 20, seed=3)
    assert ev1 == ev2
    joined = apply_events(ov1, ev1)
    assert len(joined) == sum(1 for e in ev1 if e.kind == "join")
    with pytest.raises(ValueError, match="unknown session event"):
        apply_events(ov1, [SessionEvent("flap")])


def test_repair_reconnect_preserves_connectivity():
    ov = Overlay(_path_topology(12))
    ov.remove_peer(6, repair="reconnect")   # interior peer of the path
    _, _, reached = bfs_tree(ov.top, 0, ov.n)
    assert reached.sum() == 11              # everyone but the tombstone
    ov2 = Overlay(_path_topology(12))
    ov2.remove_peer(6, repair="none")
    _, _, reached2 = bfs_tree(ov2.top, 0, ov2.n)
    assert reached2.sum() == 6              # split: only the left half


def test_registry_surface_uniform():
    assert "reconnect" in registry.available_repairs()
    assert "none" in registry.available_repairs()
    assert registry.get_repair("reconnect") is not None
    with pytest.raises(KeyError, match="registered"):
        registry.get_repair("nope")
    assert set(registry.available_placements()) >= {"random", "neighbor"}
    with pytest.raises(KeyError, match="registered"):
        registry.get_placement("nope")
    # the pre-existing registries resolve through the same module
    assert "fd-dynamic" in registry.available_policies()
    assert "waxman" in registry.available_topologies()


# --------------------------------------------------------------------------
# replication
# --------------------------------------------------------------------------

@pytest.mark.parametrize("placement", ["random", "neighbor"])
def test_replication_parity_all_backends(placement):
    top = barabasi_albert(80, m=2, seed=10)
    params = dataclasses.replace(PA, replication_factor=2,
                                 replication_placement=placement)
    plan = NetworkPlan(top)
    _assert_plans_agree(plan, top, (0, 11), params=params,
                        lifetime_mean_s=15.0)


def test_replication_improves_accuracy_under_churn():
    top = barabasi_albert(150, m=2, seed=12)
    pol = get_policy("fd-dynamic").variant(lifetime_mean_s=8.0)
    spec = QuerySpec(origins=(0, 9, 33), n_trials=4, rng="independent")
    accs = {}
    for r in (0, 3):
        params = dataclasses.replace(PA, replication_factor=r)
        accs[r] = SimEngine(top, params).run(spec, pol) \
            .metrics.accuracy.mean()
    assert accs[3] >= accs[0]
    assert accs[0] < 1.0                    # churn actually bites here


def test_replication_zero_is_bit_identical_to_default():
    # r=0 must leave every drawn bit unchanged (placement table unused)
    top = barabasi_albert(60, m=2, seed=13)
    pol = get_policy("fd-dynamic").variant(lifetime_mean_s=20.0)
    spec = QuerySpec(origins=(0, 7), n_trials=2, rng="independent")
    base = SimEngine(top, PA).run(spec, pol).metrics
    zero = SimEngine(top, dataclasses.replace(
        PA, replication_factor=0)).run(spec, pol).metrics
    for f in _FIELDS:
        np.testing.assert_array_equal(getattr(base, f), getattr(zero, f))


def test_replica_table_cached_and_deterministic():
    top = barabasi_albert(50, m=2, seed=14)
    plan = NetworkPlan(top)
    p2 = dataclasses.replace(PA, replication_factor=2)
    t1 = plan.replica_table(p2)
    assert t1.shape == (50, 2) and plan.replica_table(p2) is t1
    assert plan.replica_table(PA) is None   # r=0: no table
    # no self-replicas, and a rebuild reproduces the same table
    assert (t1 != np.arange(50)[:, None]).all()
    np.testing.assert_array_equal(NetworkPlan(top).replica_table(p2), t1)


def test_engine_syncs_live_overlay_between_queries():
    ov = Overlay(barabasi_albert(70, m=2, seed=15))
    eng = SimEngine(ov, PA)                 # engine bound to the overlay
    r1 = eng.run(QuerySpec(origins=(0,)), "fd-st1+2")
    ov.remove_peer(int(ov.top.neighbors[0][0]))
    r2 = eng.run(QuerySpec(origins=(0,)), "fd-st1+2")   # auto re-synced
    assert eng.plan.version == ov.version
    fresh = SimEngine(NetworkPlan(ov.top), PA).run(
        QuerySpec(origins=(0,)), "fd-st1+2")
    assert r2.query_metrics(0, 0) == fresh.query_metrics(0, 0)
    assert r1.metrics.n_reached[0, 0] >= r2.metrics.n_reached[0, 0]
