"""Always-on serving layer (ISSUE 6): Engine.run_many dynamic batching
+ the QueryServer.

  * ``run_many`` over mixed policies is entry-wise BIT-EXACT with
    sequential ``run()`` calls in every RNG mode (shared batch-of-1,
    independent streams, explicit seed grids, shared multi-entry) on
    the numpy AND jax backends — and coalescing really happens
    (``batch_size > 1``);
  * the server sheds deterministically at the queue bound, times out
    deterministically at dispatch, drains on stop, and serves bits
    identical to direct ``run()``;
  * the legacy shims emit ``DeprecationWarning``s naming the
    QuerySpec+engine replacement;
  * the ``overlay`` launch subcommand serves a mixed stream end to end.
"""
import numpy as np
import pytest

from repro.engine import (Engine, QueryServer, QuerySpec, RequestTimeout,
                          ServerClosed, ServerConfig, ServerOverloaded,
                          SimEngine, TopKResult, get_policy)
from repro.p2psim import SimParams, barabasi_albert

TOP = barabasi_albert(220, m=2, seed=7)
JTOP = barabasi_albert(96, m=2, seed=3)      # small: keeps jit compiles fast
PA = SimParams(seed=11)

_PARITY_FIELDS = ("n_reached", "n_edges_pq", "m_fw", "m_bw", "m_rt",
                  "b_fw", "b_bw", "b_rt", "response_time_s", "accuracy")

# one spec per RNG mode: shared batch-of-1 and the independent/seeded
# modes coalesce; the shared multi-entry spec must run solo
MIXED_SPECS = [
    QuerySpec(origins=(0,), seed=3),                       # shared, 1 entry
    QuerySpec(origins=(17,), seed=9),                      # shared, 1 entry
    QuerySpec(origins=(5, 41), n_trials=2,
              rng="independent", seed=2),                  # independent
    QuerySpec(origins=(9,), n_trials=2, seeds=[[7, 19]]),  # seed grid
    QuerySpec(origins=(3, 12), n_trials=2, seed=5),        # shared multi
    QuerySpec(origins=(29,), seed=3),                      # shared, 1 entry
]
MIXED_POLS = ["fd-dynamic", "fd-dynamic", "fd-dynamic", "fd-dynamic",
              "fd-dynamic", "cn"]


def _assert_same_bits(a, b, ctx=""):
    for f in _PARITY_FIELDS:
        np.testing.assert_array_equal(
            getattr(a.metrics, f), getattr(b.metrics, f),
            err_msg=f"{ctx}: field {f}")


# --------------------------------------------------------------------------
# Engine.run_many: batching changes scheduling, never bits
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_run_many_bit_exact_vs_sequential_all_rng_modes(backend):
    top = TOP if backend == "numpy" else JTOP
    engine = SimEngine(top, PA, backend=backend)
    fused = engine.run_many(MIXED_SPECS, MIXED_POLS)
    solo = [engine.run(s, p) for s, p in zip(MIXED_SPECS, MIXED_POLS)]
    for i, (f, s) in enumerate(zip(fused, solo)):
        _assert_same_bits(f, s, f"{backend} request {i}")
    # the three coalescable fd-dynamic singles+grids fused; the shared
    # multi-entry spec and the lone cn request did not
    sizes = [r.batch_size for r in fused]
    assert max(sizes) > 1, sizes
    assert sizes[4] == 1            # shared multi-entry ran solo
    assert all(isinstance(r, TopKResult) for r in fused)


def test_run_many_mixed_policies_group_separately():
    engine = SimEngine(TOP, PA)
    specs = [QuerySpec(origins=(o,), seed=s)
             for s, o in enumerate((0, 7, 42, 3, 12, 9))]
    pols = ["fd-dynamic", "cn", "fd-dynamic", "cn", "fd-dynamic", "cn"]
    fused = engine.run_many(specs, pols)
    for f, spec, pol in zip(fused, specs, pols):
        _assert_same_bits(f, engine.run(spec, pol), pol)
        assert f.policy == pol
        assert f.batch_size == 3    # 3 per policy group
    # a single policy string broadcasts across all specs
    one = engine.run_many(specs[:2], "cn-star")
    assert [r.policy for r in one] == ["cn-star", "cn-star"]


def test_run_many_policy_length_mismatch_raises():
    engine = SimEngine(TOP, PA)
    with pytest.raises(ValueError, match="2 specs but 1 policies"):
        engine.run_many([QuerySpec(), QuerySpec()], ["cn"])


def test_run_many_fd_stats_never_coalesces():
    engine = SimEngine(TOP, PA)
    specs = [QuerySpec(origins=(0,), seed=1),
             QuerySpec(origins=(7,), seed=2)]
    fused = engine.run_many(specs, "fd-stats")
    for f, spec in zip(fused, specs):
        assert f.batch_size == 1
        ref = engine.run(spec, "fd-stats")
        assert f.extras["comm_reduction"] == ref.extras["comm_reduction"]
        assert f.extras["accuracy"] == ref.extras["accuracy"]


def test_topkresult_serving_metadata_populated():
    engine = SimEngine(TOP, PA)
    res = engine.run(QuerySpec(origins=(0,), seed=1))
    assert res.batch_size == 1 and res.queue_s == 0.0
    assert res.run_s > 0.0 and res.compile_s >= 0.0
    fused = engine.run_many(
        [QuerySpec(origins=(o,), seed=i)
         for i, o in enumerate((0, 7, 42))], "fd-dynamic")
    assert all(r.batch_size == 3 for r in fused)
    assert all(r.run_s > 0.0 for r in fused)


def test_engine_abc_default_run_many_loops():
    class Scalar(Engine):
        backend = "scalar"

        def run(self, spec=None, policy="fd-dynamic", **kw):
            return SimEngine(TOP, PA).run(spec, policy)

    out = Scalar().run_many([QuerySpec(origins=(0,), seed=1)] * 2, "cn")
    assert [r.batch_size for r in out] == [1, 1]


# --------------------------------------------------------------------------
# QueryServer: queueing, shedding, timeouts, parity
# --------------------------------------------------------------------------

def test_server_serves_bits_identical_to_run():
    engine = SimEngine(TOP, PA)
    with QueryServer(engine) as server:
        handles = [server.submit(s, p)
                   for s, p in zip(MIXED_SPECS, MIXED_POLS)]
        results = [h.result(timeout=60) for h in handles]
        m = server.metrics()
    for i, (res, spec, pol) in enumerate(
            zip(results, MIXED_SPECS, MIXED_POLS)):
        _assert_same_bits(res, engine.run(spec, pol), f"request {i}")
        assert res.queue_s >= 0.0
    assert m.served == len(MIXED_SPECS)
    assert sum(m.batch_hist.values()) == len(MIXED_SPECS)


def test_server_sheds_deterministically_at_queue_bound():
    # submit before start(): the queue fills with the dispatcher idle,
    # so exactly max_queue requests are admitted and the next one sheds
    server = QueryServer(SimEngine(TOP, PA),
                         ServerConfig(max_queue=4))
    handles = [server.submit(QuerySpec(origins=(i,), seed=i), "cn")
               for i in range(4)]
    with pytest.raises(ServerOverloaded, match="queue full"):
        server.submit(QuerySpec(origins=(9,), seed=9), "cn")
    server.start()
    assert all(h.result(timeout=60) is not None for h in handles)
    m = server.metrics()
    assert m.shed == 1 and m.served == 4
    server.stop()


def test_server_times_out_expired_requests_at_dispatch():
    engine = SimEngine(TOP, PA)
    with QueryServer(engine) as server:
        h = server.submit(QuerySpec(origins=(0,), seed=1), "cn",
                          timeout_s=0)      # deadline already passed
        with pytest.raises(RequestTimeout):
            h.result(timeout=60)
        assert h.done() and isinstance(h.exception(), RequestTimeout)
        ok = server.query(QuerySpec(origins=(0,), seed=1), "cn")
        m = server.metrics()
    assert m.timed_out == 1 and m.served == 1
    _assert_same_bits(ok, engine.run(QuerySpec(origins=(0,), seed=1),
                                     "cn"))


def test_server_default_timeout_from_config():
    server = QueryServer(SimEngine(TOP, PA),
                         ServerConfig(default_timeout_s=0.0))
    h = server.submit(QuerySpec(origins=(0,), seed=1), "cn")
    server.start()
    with pytest.raises(RequestTimeout):
        h.result(timeout=60)
    server.stop()


def test_server_drains_queue_on_stop_and_then_refuses():
    server = QueryServer(SimEngine(TOP, PA))
    hs = [server.submit(QuerySpec(origins=(i,), seed=i), "cn")
          for i in range(3)]
    server.start()
    server.stop()                     # drain=True: pending work finishes
    assert all(h.done() for h in hs)
    assert [h.result() is not None for h in hs] == [True] * 3
    with pytest.raises(ServerClosed):
        server.submit(QuerySpec(), "cn")


def test_server_batches_concurrent_requests_onto_one_sweep():
    server = QueryServer(SimEngine(TOP, PA),
                         ServerConfig(batch_window_s=0.05))
    hs = [server.submit(QuerySpec(origins=(o,), seed=i), "fd-dynamic")
          for i, o in enumerate((0, 7, 42, 99, 3, 12, 5, 31))]
    server.start()                    # whole backlog dispatched at once
    results = [h.result(timeout=60) for h in hs]
    m = server.metrics()
    assert max(r.batch_size for r in results) > 1
    assert m.mean_batch > 1.0 and m.max_batch > 1
    assert m.latency.p99_s >= m.latency.p50_s
    server.stop()


def test_server_multi_engine_routing():
    engines = {"a": SimEngine(TOP, PA), "b": SimEngine(JTOP, PA)}
    with QueryServer(engines) as server:
        ra = server.query(QuerySpec(origins=(0,), seed=1), "cn",
                          engine="a")
        rb = server.query(QuerySpec(origins=(0,), seed=1), "cn",
                          engine="b")
        with pytest.raises(ValueError, match="name one"):
            server.submit(QuerySpec(), "cn")      # ambiguous
        with pytest.raises(KeyError, match="unknown engine"):
            server.submit(QuerySpec(), "cn", engine="zz")
    _assert_same_bits(ra, engines["a"].run(QuerySpec(origins=(0,),
                                                     seed=1), "cn"))
    assert not np.array_equal(ra.metrics.n_reached, rb.metrics.n_reached)


def test_server_warm_populates_plan_before_load():
    engine = SimEngine(TOP, PA)
    server = QueryServer(engine)
    res = server.warm(QuerySpec(origins=(0,), seed=1), "fd-dynamic")
    assert res.batch_size == 1
    assert engine.plan.cache_info()["origin_statics"] >= 1
    server.stop()


def test_warm_batch_sizes_pretrace_fused_buckets():
    """``warm(batch_sizes=...)`` pre-traces the power-of-two dispatch
    buckets on the jax backend, so COALESCED live dispatches retrace
    nothing: every served result reports ``compile_s == 0`` and no new
    jax traces."""
    engine = SimEngine(JTOP, PA, backend="jax")
    server = QueryServer(engine, ServerConfig(batch_window_s=0.05))
    spec = QuerySpec(origins=(0,), seed=1)
    warmed = server.warm(spec, "fd-dynamic", batch_sizes=(1, 8))
    assert warmed.batch_size == 8
    # backlog submitted before start -> one coalesced dispatch.  All
    # requests hit the warmed origin: bucket-warming covers the FUSED
    # BATCH SHAPES; a brand-new origin still (correctly) pays its own
    # statics compile.
    hs = [server.submit(QuerySpec(origins=(0,), seed=i), "fd-dynamic")
          for i in range(5)]
    server.start()
    results = [h.result(timeout=120) for h in hs]
    server.stop()
    assert max(r.batch_size for r in results) > 1     # really coalesced
    for r in results:
        assert r.compile_s == 0, (r.batch_size, r.compile_s)
        assert "jax_traces" not in r.extras


def test_server_propagates_engine_errors_to_the_handle():
    with QueryServer(SimEngine(TOP, PA)) as server:
        h = server.submit(QuerySpec(origins=(10 ** 9,), seed=1), "cn")
        with pytest.raises(Exception):
            h.result(timeout=60)
        ok = server.query(QuerySpec(origins=(0,), seed=1), "cn")
    assert ok is not None and server.metrics().failed == 1


# --------------------------------------------------------------------------
# deprecated shims
# --------------------------------------------------------------------------

def test_legacy_shims_raise_without_escape_hatch(monkeypatch):
    from repro.p2psim import (run_queries, run_query,
                              run_statistics_heuristic)
    monkeypatch.delenv("REPRO_LEGACY_API", raising=False)
    with pytest.raises(RuntimeError, match="REPRO_LEGACY_API"):
        run_query(TOP, 0, PA)
    with pytest.raises(RuntimeError, match="REPRO_LEGACY_API"):
        run_queries(TOP, [0], PA, 1)
    with pytest.raises(RuntimeError, match="REPRO_LEGACY_API"):
        run_statistics_heuristic(TOP, 0, PA, 0.8)


def test_legacy_shims_warn_and_delegate_under_escape_hatch(monkeypatch):
    from repro.p2psim import (run_queries, run_query,
                              run_statistics_heuristic)
    monkeypatch.setenv("REPRO_LEGACY_API", "1")
    with pytest.warns(DeprecationWarning, match="SimEngine"):
        met, _ = run_query(TOP, 0, PA)
    with pytest.warns(DeprecationWarning, match="QuerySpec"):
        bm = run_queries(TOP, [0], PA, 1)
    with pytest.warns(DeprecationWarning, match="fd-stats"):
        run_statistics_heuristic(TOP, 0, PA, 0.8)
    # the escape hatch must not change bits: shim == engine
    res = SimEngine(TOP, PA).run(QuerySpec(origins=(0,)), "fd-dynamic")
    assert res.query_metrics(0, 0) == met
    np.testing.assert_array_equal(bm.m_fw, res.metrics.m_fw)


# --------------------------------------------------------------------------
# launch entrypoint
# --------------------------------------------------------------------------

def test_launch_overlay_serves_mixed_stream():
    from repro.launch import serve as serve_mod
    metrics = serve_mod.main([
        "overlay", "--topology", "ba,small-world", "--n-peers", "200",
        "--requests", "24", "--concurrency", "8",
        "--policies", "fd-dynamic,cn", "--batch-window-ms", "5"])
    assert metrics["served"] == 24
    assert metrics["shed"] == 0 and metrics["timed_out"] == 0
    assert metrics["throughput_qps"] > 0
    assert metrics["max_batch"] >= 1
    assert metrics["latency"]["p50_s"] > 0


# --------------------------------------------------------------------------
# DeviceEngine.run_many: stacked collective == per-request calls
# --------------------------------------------------------------------------

def test_device_engine_run_many_batches_bit_exact(devices8):
    out = devices8("""
import jax, numpy as np
from repro.engine import DeviceEngine, QuerySpec
from repro.jaxcompat import make_mesh

mesh = make_mesh((8,), ("model",))
eng = DeviceEngine(mesh)
scores = [jax.random.normal(jax.random.PRNGKey(i), (1024,))
          for i in range(4)]
specs = [QuerySpec(k=20)] * 4
pols = ["fd-dynamic", "fd-basic", "cn", "fd-st1"]   # fd-* share a group
fused = eng.run_many(specs, pols, scores=scores)
for i, (s, p) in enumerate(zip(scores, pols)):
    solo = eng.run(QuerySpec(k=20), p, scores=s)
    np.testing.assert_array_equal(np.asarray(fused[i].values),
                                  np.asarray(solo.values))
    np.testing.assert_array_equal(np.asarray(fused[i].indices),
                                  np.asarray(solo.indices))
sizes = [r.batch_size for r in fused]
assert sizes[0] == 3 and sizes[1] == 3 and sizes[3] == 3, sizes
assert sizes[2] == 1                       # cn lowers to its own program
assert all(r.run_s > 0 for r in fused)
try:
    eng.run_many(specs, pols, scores=scores[:2])
    raise SystemExit("scores length mismatch must raise")
except ValueError:
    pass
print("DEVICE_RUN_MANY_OK")
""")
    assert "DEVICE_RUN_MANY_OK" in out
