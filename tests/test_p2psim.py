"""Paper-faithful reproduction checks: Lemmas 1–4, Theorem 1, §3.2 byte
model, §5 figures' trends (scaled down for CI speed)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.engine import QuerySpec, SimEngine, get_policy, policy_from_legacy
from repro.optim.compress import inflate_k
from repro.p2psim import SimParams, barabasi_albert, waxman
from repro.p2psim.graph import bfs_tree, eccentricity_ttl
from repro.p2psim.simulate import local_topk_scores

TOP = barabasi_albert(600, m=2, seed=7)
PA = SimParams(seed=11)


def run_query(top, origin, params=None, *, algorithm="fd",
              strategy="st1+2", dynamic=True,
              lifetime_mean_s=float("inf")):
    """One scalar query through the engine (the retired ``run_query``
    shim's semantics — same bits, current API)."""
    pol = policy_from_legacy(algorithm, strategy, dynamic, lifetime_mean_s)
    res = SimEngine(top, params).run(QuerySpec(origins=(int(origin),)), pol)
    return res.metrics.query_metrics(0, 0), None


def run_statistics_heuristic(top, origin, params, z):
    """Engine fd-stats policy, unpacked to the legacy 4-tuple."""
    res = SimEngine(top, params).run(QuerySpec(origins=(int(origin),)),
                                     get_policy("fd-stats").variant(z=z))
    ex = res.extras
    return (ex["metrics_full"], ex["metrics_pruned"],
            ex["comm_reduction"], ex["accuracy"])


def test_topology_degree():
    assert 3.5 < TOP.avg_degree() < 4.5          # paper's d(G) = 4
    w = waxman(300, seed=3)
    assert 2.0 < w.avg_degree() < 8.0
    # connected: bfs reaches everyone
    _, _, reached = bfs_tree(w, 0, w.n)
    assert reached.all()


def test_lemma1_basic_forward_count():
    # Lemma 1 assumes every reached peer forwards (TTL exceeds all
    # depths); at TTL == eccentricity the deepest peers get ttl_rem == 0
    pa = SimParams(seed=11, ttl=eccentricity_ttl(TOP, 0) + 1)
    met, _ = run_query(TOP, 0, pa, strategy="basic", dynamic=False)
    # exact form: sum_p (d(p)-1) + 1  ==  (d(G)-1)|P_Q| + 1
    degs = TOP.degree()
    exact = int(degs.sum() - met.n_reached + 1)
    assert met.m_fw == exact
    approx = (met.avg_degree - 1) * met.n_reached + 1
    assert abs(met.m_fw - approx) / exact < 0.01


def test_lemma2_lower_bound():
    met, _ = run_query(TOP, 0, PA, strategy="st1+2", dynamic=False)
    assert met.m_fw >= met.n_reached - 1         # Lemma 2


def test_lemma3_strategy1_edges_once():
    met, _ = run_query(TOP, 0, PA, strategy="st1", dynamic=False)
    # w.h.p. each edge exactly once -> |E|; allow the paper's "low
    # probability" simultaneous sends
    assert met.n_edges_pq <= met.m_fw <= 1.02 * met.n_edges_pq


def test_theorem1_strategy12_below_E():
    met1, _ = run_query(TOP, 0, PA, strategy="st1", dynamic=False)
    met12, _ = run_query(TOP, 0, PA, strategy="st1+2", dynamic=False)
    assert met12.m_fw <= met1.m_fw
    assert met12.m_fw <= met1.n_edges_pq         # Theorem 1


def test_backward_messages_and_bytes():
    met, _ = run_query(TOP, 0, PA, dynamic=False)
    assert met.m_bw == met.n_reached - 1         # m_bw = |P_Q| - 1
    assert met.b_bw == PA.k * 10 * (met.n_reached - 1)   # b_bw = k L (n-1)


def test_retrieve_bound():
    met, _ = run_query(TOP, 0, PA)
    assert met.m_rt <= 2 * PA.k                  # m_rt <= 2k


def test_paper_2mb_example():
    """§3.2: 10k peers, k=20, L=10 -> b_bw < 2 MB (we run 2k, scaled)."""
    top = barabasi_albert(2000, m=2, seed=1)
    met, _ = run_query(top, 0, PA, dynamic=False)
    scaled = met.b_bw * (10000 / met.n_reached)
    assert scaled < 2e6


def test_fd_beats_cn_cnstar():
    fd, _ = run_query(TOP, 0, PA)
    cn, _ = run_query(TOP, 0, PA, algorithm="cn")
    cns, _ = run_query(TOP, 0, PA, algorithm="cn_star")
    assert fd.total_bytes < cns.total_bytes < cn.total_bytes
    assert fd.response_time_s < cns.response_time_s < cn.response_time_s
    assert fd.accuracy == 1.0


def test_fig6_strategy_reduction():
    """Str1+2 cuts communication vs basic (paper: ~30% at 10k)."""
    b, _ = run_query(TOP, 0, PA, strategy="basic", dynamic=False)
    s12, _ = run_query(TOP, 0, PA, strategy="st1+2", dynamic=False)
    red = 1 - s12.total_bytes / b.total_bytes
    assert 0.10 < red < 0.60


def test_fig7_statistics_heuristic():
    _, _, reduction, acc = run_statistics_heuristic(TOP, 0, PA, z=0.8)
    assert reduction > 0.15
    assert acc > 0.80                            # paper: >90% at z=0.8
    # z=0 prunes everything except what the originator holds
    _, m0, red0, acc0 = run_statistics_heuristic(TOP, 0, PA, z=0.0)
    assert red0 > reduction
    assert acc0 < acc


def test_fig8_dynamicity():
    accs_b, accs_d = [], []
    for lt in (30.0, 300.0):
        mb, _ = run_query(TOP, 0, PA, dynamic=False, lifetime_mean_s=lt)
        md, _ = run_query(TOP, 0, PA, dynamic=True, lifetime_mean_s=lt)
        accs_b.append(mb.accuracy)
        accs_d.append(md.accuracy)
    assert accs_d[0] >= accs_b[0]                # dynamic >= basic
    assert accs_d[1] >= 0.95                     # ~1 for long lifetimes
    assert accs_b[0] < 1.0                       # churn hurts basic


def test_lemma4_k_inflation():
    assert inflate_k(20, 0.0) == 20
    assert inflate_k(20, 0.2) == 25              # paper: k/(1-P)
    assert inflate_k(20, 0.5) == 40
    with pytest.raises(ValueError):
        inflate_k(20, 1.0)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 10 ** 6), k=st.integers(1, 64),
       seed=st.integers(0, 99))
def test_order_statistics_sampler(n, k, seed):
    """top-k of n uniforms: descending, in (0,1], E[max] = n/(n+1)."""
    rng = np.random.default_rng(seed)
    s = local_topk_scores(np.array([n] * 50), min(k, n), rng)
    assert (np.diff(s, axis=1) <= 1e-12).all()
    assert (s > 0).all() and (s <= 1).all()
    if n >= 1000:
        assert abs(s[:, 0].mean() - n / (n + 1)) < 0.05


def test_ttl_coverage():
    """TTL=12 reaches 10k peers (paper §5.1) — scaled: eccentricity is
    O(log n) for BA graphs."""
    ttl = eccentricity_ttl(TOP, 0)
    assert ttl <= 12
    _, depth, reached = bfs_tree(TOP, 0, ttl)
    assert reached.all()


# --------------------------------------------------------------------------
# topology edge cases (ISSUE 3): adversarial Waxman corners + auto-TTL
# agreement between the NetworkPlan and the scalar bfs_tree path
# --------------------------------------------------------------------------

def _is_connected(top):
    _, _, reached = bfs_tree(top, 0, top.n)
    return bool(reached.all())


@pytest.mark.parametrize("alpha,beta", [
    (0.01, 0.9),    # near-zero decay length: edges only between twins
    (0.01, 0.01),   # ... and almost no edges at all
    (5.0, 1e-4),    # flat decay but vanishing base probability
    (5.0, 0.999),   # dense regime
    (1e-4, 1e-4),   # both corners at once
])
def test_waxman_adversarial_corners_connected(alpha, beta):
    """Post-connection bridging must yield ONE component for (alpha,
    beta) corners where the raw Waxman draw is wildly under- or
    over-connected."""
    for seed in (0, 1):
        top = waxman(60, alpha=alpha, beta=beta, seed=seed)
        assert top.n == 60
        assert _is_connected(top), (alpha, beta, seed)
        # bridging adds edges, never nodes or duplicate arcs
        for u in range(top.n):
            nb = top.neighbors[u]
            assert len(np.unique(nb)) == len(nb)
            assert u not in nb


def test_waxman_corner_still_simulates():
    """A bridged near-empty Waxman graph (long chains) must survive a
    full query simulation with auto TTL."""
    top = waxman(40, alpha=0.01, beta=0.01, seed=5)
    met, _ = run_query(top, 0, SimParams(seed=1, k=5))
    assert met.n_reached == 40
    assert met.accuracy == 1.0


def test_auto_ttl_plan_vs_scalar_agreement():
    """NetworkPlan.auto_ttl / origin_statics resolve ttl=0 to the SAME
    eccentricity as the scalar bfs path, on both generators."""
    from repro.engine import NetworkPlan
    for top in (barabasi_albert(80, m=2, seed=2),
                waxman(50, alpha=0.05, beta=0.08, seed=4),
                waxman(30, alpha=0.01, beta=0.01, seed=0)):
        plan = NetworkPlan(top)
        for origin in (0, top.n // 2, top.n - 1):
            ecc = eccentricity_ttl(top, origin)
            assert plan.auto_ttl(origin) == ecc, (top.kind, origin)
        # origin_statics' ttl resolution agrees with auto_ttl and the
        # cached value is shared between both entry points
        sts, _ = plan.origin_statics(
            np.array([0, top.n - 1]), 0, "st1+2")
        assert sts[0].ttl == plan.auto_ttl(0)
        assert sts[1].ttl == plan.auto_ttl(top.n - 1)
        # a fresh plan resolving via origin_statics first also matches
        plan2 = NetworkPlan(top)
        sts2, _ = plan2.origin_statics(np.array([0]), 0, "st1+2")
        assert plan2.auto_ttl(0) == sts2[0].ttl == eccentricity_ttl(top, 0)
