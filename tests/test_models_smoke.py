"""Per-architecture smoke tests (assignment requirement): reduced
same-family config, one forward/train step on CPU, output shapes + no
NaNs; plus a decode step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, get_config, list_archs, smoke_config, shape_applicable
from repro.models import model as M
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.runtime.steps import make_train_step

ARCHS = list_archs()


def _batch(cfg, b=2, s=16, seed=0):
    key = jax.random.PRNGKey(seed)
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model))
    if cfg.mrope_sections is not None:
        batch["vision_embeds"] = jax.random.normal(key, (b, 4, cfg.d_model))
    return batch


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = smoke_config(get_config(arch))
    params = M.init_params(jax.random.PRNGKey(0), cfg, max_seq=64)
    batch = _batch(cfg)
    logits, _, aux = M.forward(params, cfg, batch, mode="train")
    assert logits.shape == (2, 16, cfg.padded_vocab())
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_loss(arch):
    cfg = smoke_config(get_config(arch))
    params = M.init_params(jax.random.PRNGKey(0), cfg, max_seq=64)
    opt_cfg = AdamWConfig(lr=5e-3, warmup_steps=0, total_steps=10)
    opt = adamw_init(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg, batch_axes=()))
    batch = _batch(cfg)
    losses = []
    for i in range(3):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = smoke_config(get_config(arch))
    params = M.init_params(jax.random.PRNGKey(0), cfg, max_seq=64)
    state = M.init_decode_state(cfg, batch=2, s_max=32,
                                cache_dtype=jnp.float32)
    tok = jnp.ones((2, 1), jnp.int32)
    logits, state2 = M.decode_step(params, cfg, state, tok)
    assert logits.shape == (2, 1, cfg.padded_vocab())
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert int(state2.pos) == 1
    # a second step advances
    _, state3 = M.decode_step(params, cfg, state2, tok)
    assert int(state3.pos) == 2


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "rwkv6-3b",
                                  "recurrentgemma-2b", "minicpm3-4b"])
def test_prefill_decode_consistency(arch):
    """Decoding token-by-token must match teacher-forced forward logits."""
    cfg = smoke_config(get_config(arch))
    params = M.init_params(jax.random.PRNGKey(0), cfg, max_seq=64)
    s = 8
    batch = _batch(cfg, b=1, s=s)
    full_logits, _, _ = M.forward(params, cfg, batch, mode="train")
    state = M.init_decode_state(cfg, batch=1, s_max=s + 1,
                                cache_dtype=jnp.float32)
    outs = []
    for t in range(s):
        lg, state = M.decode_step(params, cfg, state,
                                  batch["tokens"][:, t:t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               atol=2e-2, rtol=2e-2)


def test_shape_applicability_rules():
    """long_500k runs only for ssm/hybrid archs (DESIGN.md §5)."""
    long = SHAPES["long_500k"]
    allowed = {a for a in ARCHS
               if shape_applicable(get_config(a), long)}
    assert allowed == {"rwkv6-3b", "recurrentgemma-2b"}


def test_param_count_close_to_tree():
    for arch in ["qwen2-0.5b", "phi3-medium-14b", "rwkv6-3b"]:
        cfg = get_config(arch)
        smoke = smoke_config(cfg)
        params = M.init_params(jax.random.PRNGKey(0), smoke, max_seq=32)
        n_tree = M.count_params(params)
        n_est = smoke.param_count()
        assert abs(n_tree - n_est) / n_tree < 0.30, (arch, n_tree, n_est)
