"""Pallas blocked top-k kernel vs pure-jnp oracle: shape/dtype sweeps +
hypothesis property tests (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.topk import local_topk, topk_pallas, topk_ref


@pytest.mark.parametrize("shape", [(128,), (1, 1000), (3, 777), (2, 4, 4096)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
@pytest.mark.parametrize("k", [1, 8, 20])
def test_topk_matches_ref(shape, dtype, k):
    if k > shape[-1]:
        pytest.skip("k > n")
    x = jax.random.normal(jax.random.PRNGKey(0), shape).astype(dtype)
    v1, i1 = topk_pallas(x, k, tile_n=256)
    v2, i2 = topk_ref(x, k)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=0, atol=0)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


@pytest.mark.parametrize("tile_n", [128, 256, 1024, 4096])
def test_topk_tile_sizes(tile_n):
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3000))
    v1, i1 = topk_pallas(x, 16, tile_n=tile_n)
    v2, i2 = topk_ref(x, 16)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_topk_index_offset():
    x = jax.random.normal(jax.random.PRNGKey(2), (512,))
    v, i = topk_pallas(x, 4, index_offset=1000, tile_n=128)
    v2, i2 = topk_ref(x, 4, index_offset=1000)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i2))
    assert int(np.min(np.asarray(i))) >= 1000


def test_topk_with_ties_prefers_lowest_index():
    x = jnp.zeros((64,)).at[jnp.array([5, 17])].set(1.0)
    v, i = topk_pallas(x, 3, tile_n=128)
    assert list(np.asarray(i)[:2]) == [5, 17]


def test_topk_duplicate_values():
    x = jnp.array([3.0, 3.0, 3.0, 1.0, 2.0])
    v, i = topk_pallas(x, 4, tile_n=128)
    np.testing.assert_allclose(np.asarray(v), [3, 3, 3, 2])
    assert sorted(np.asarray(i)[:3].tolist()) == [0, 1, 2]


@settings(max_examples=30, deadline=None)
@given(n=st.integers(4, 600), k=st.integers(1, 16), seed=st.integers(0, 99))
def test_topk_property(n, k, seed):
    k = min(k, n)
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    v, i = topk_pallas(x, k, tile_n=128)
    v, i = np.asarray(v), np.asarray(i)
    xs = np.asarray(x)
    # values are the k largest, descending, and indices point at them
    assert np.all(np.diff(v) <= 0)
    np.testing.assert_allclose(xs[i], v, rtol=1e-6)
    np.testing.assert_allclose(np.sort(xs)[::-1][:k], v, rtol=1e-6)


def test_local_topk_dispatch():
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 256))
    v1, i1 = local_topk(x, 5, use_pallas=True)
    v2, i2 = local_topk(x, 5, use_pallas=False)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
