"""End-to-end system behaviour: the training driver learns + checkpoints
+ resumes; the serving driver decodes with FD sampling; elastic restore."""
import sys

import jax
import numpy as np
import pytest


def test_train_driver_learns_and_resumes(tmp_path, monkeypatch):
    from repro.launch import train as train_mod
    argv = ["train", "--arch", "qwen1.5-0.5b", "--smoke", "--steps", "30",
            "--batch", "4", "--seq", "64", "--lr", "3e-3",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "10",
            "--log-every", "10"]
    monkeypatch.setattr(sys, "argv", argv)
    losses = train_mod.main()
    assert losses[-1] < losses[0]                # learns the copy task
    # resume: second run starts from the last checkpoint, runs the rest
    argv2 = list(argv)
    argv2[argv2.index("--steps") + 1] = "35"
    monkeypatch.setattr(sys, "argv", argv2)
    losses2 = train_mod.main()
    assert len(losses2) <= 10                    # only the remaining steps


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "recurrentgemma-2b"])
def test_serve_driver_decodes(arch, monkeypatch):
    from repro.launch import serve as serve_mod
    monkeypatch.setattr(sys, "argv", [
        "serve", "--arch", arch, "--smoke", "--batch", "2",
        "--prompt-len", "12", "--gen", "6"])
    toks = serve_mod.main()
    assert toks.shape == (2, 6)
    assert (toks >= 0).all()


def test_elastic_checkpoint_restore(tmp_path):
    """A checkpoint written under one sharding restores onto another
    mesh (elastic re-meshing) with identical values."""
    from repro.ckpt.checkpoint import restore, save
    from repro.ckpt.elastic import reshard_tree
    from repro.configs.base import get_config, smoke_config
    from repro.launch.mesh import make_host_mesh
    from repro.models import model as M

    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg, max_seq=32)
    save(str(tmp_path), 0, params)
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    got = restore(str(tmp_path), 0, like)
    new_mesh = make_host_mesh(model=1)
    resharded = reshard_tree(got, cfg, new_mesh)
    np.testing.assert_array_equal(np.asarray(resharded["embed"]),
                                  np.asarray(params["embed"]))
