"""Batched multi-query engine: exactness, throughput, comm model.

Covers the PR-1 acceptance criteria (all parity is asserted against the
scalar ``run_query_reference`` — ``run_query`` itself is now a shim over
the same engine, see tests/test_engine.py):
  * batch-of-1 reproduces the reference bit-for-bit (both RNG modes);
  * independent-streams entries reproduce the reference entry-by-entry;
  * 64 queries x 4 trials on 256 peers in one call, >= 10x faster than
    a Python loop of 256 scalar-reference calls;
  * core.fd.comm_bytes matches bytes measured by walking the actual
    schedules, for CN / CN* / FD across all three schedules.
"""
import dataclasses
import time

import numpy as np
import pytest

from repro.core.fd import comm_bytes
from repro.core.topology import SCHEDULES, measure_comm_bytes
from repro.p2psim import (BatchMetrics, SimParams, barabasi_albert,
                          run_query_reference, waxman)
from repro.p2psim.graph import (as_csr, bfs_tree, bfs_tree_csr,
                                bfs_tree_csr_multi)

TOP = barabasi_albert(256, m=2, seed=7)
WAX = waxman(150, seed=3)


def run_queries(top, origins, params=None, n_trials=1, *, algorithm="fd",
                strategy="st1+2", dynamic=True,
                lifetime_mean_s=float("inf"), seeds=None,
                independent_streams=False):
    """The retired ``run_queries`` shim's semantics through the
    current engine surface (same bits — per-call plan, no caching)."""
    from repro.engine import QuerySpec, SimEngine, policy_from_legacy
    pol = policy_from_legacy(algorithm, strategy, dynamic, lifetime_mean_s)
    spec = QuerySpec(
        origins=tuple(int(o) for o in np.atleast_1d(np.asarray(origins))),
        n_trials=n_trials, seeds=seeds,
        rng="independent" if independent_streams else "shared")
    return SimEngine(top, params).run(spec, pol).metrics


# --------------------------------------------------------------------------
# vectorized BFS == scalar BFS
# --------------------------------------------------------------------------

@pytest.mark.parametrize("top", [TOP, WAX], ids=["ba", "waxman"])
def test_bfs_csr_matches_python_bfs(top):
    indptr, indices = as_csr(top)
    for origin in (0, 7, top.n - 1):
        for ttl in (2, 5, top.n):
            p1, d1, r1 = bfs_tree(top, origin, ttl)
            p2, d2, r2 = bfs_tree_csr(indptr, indices, origin, ttl)
            np.testing.assert_array_equal(p1, p2)
            np.testing.assert_array_equal(d1, d2)
            np.testing.assert_array_equal(r1, r2)


def test_bfs_multi_matches_single():
    indptr, indices = as_csr(TOP)
    origins = np.array([0, 13, 200, 13, 255], np.int64)
    P, D, R = bfs_tree_csr_multi(indptr, indices, origins, TOP.n)
    for i, o in enumerate(origins):
        p1, d1, r1 = bfs_tree_csr(indptr, indices, int(o), TOP.n)
        np.testing.assert_array_equal(P[i], p1)
        np.testing.assert_array_equal(D[i], d1)


# --------------------------------------------------------------------------
# batch-of-1 bit-for-bit regression
# --------------------------------------------------------------------------

CASES = [
    ("fd", {}),
    ("fd", dict(strategy="basic", dynamic=False)),
    ("fd", dict(strategy="st1", dynamic=False)),
    ("fd", dict(strategy="st1+2", dynamic=False)),
    ("cn", {}),
    ("cn_star", {}),
    ("fd", dict(lifetime_mean_s=60.0)),
    ("fd", dict(dynamic=False, lifetime_mean_s=60.0)),
    ("fd", dict(lifetime_mean_s=10.0)),
    ("cn", dict(lifetime_mean_s=30.0)),
]


@pytest.mark.parametrize("alg,kw", CASES,
                         ids=[f"{a}-{i}" for i, (a, _) in enumerate(CASES)])
@pytest.mark.parametrize("independent", [False, True],
                         ids=["shared", "indep"])
def test_batch_of_one_bit_for_bit(alg, kw, independent):
    for origin, seed in ((0, 0), (17, 11)):
        pa = SimParams(seed=seed)
        met, _ = run_query_reference(TOP, origin, dataclasses.replace(pa),
                           algorithm=alg, **kw)
        bm = run_queries(TOP, [origin], dataclasses.replace(pa), 1,
                         algorithm=alg, independent_streams=independent,
                         **kw)
        assert met == bm.query_metrics(0, 0)


def test_independent_entries_match_run_query_reference():
    pa = SimParams(seed=5)
    origins = np.random.default_rng(0).integers(0, TOP.n, 8)
    bm = run_queries(TOP, origins, pa, 3, independent_streams=True)
    assert isinstance(bm, BatchMetrics)
    for q in range(len(origins)):
        for t in range(3):
            met, _ = run_query_reference(
                TOP, int(origins[q]),
                dataclasses.replace(pa, seed=pa.seed + q * 3 + t))
            assert met == bm.query_metrics(q, t), (q, t)


def test_explicit_seed_grid():
    pa = SimParams(seed=0)
    seeds = np.array([[101, 202], [303, 404]])
    bm = run_queries(TOP, [0, 9], pa, 2, seeds=seeds)
    for q in range(2):
        for t in range(2):
            met, _ = run_query_reference(
                TOP, [0, 9][q],
                dataclasses.replace(pa, seed=int(seeds[q, t])))
            assert met == bm.query_metrics(q, t)


def test_shared_mode_statistically_matches_independent():
    pa = SimParams(seed=5)
    origins = np.random.default_rng(0).integers(0, TOP.n, 32)
    bi = run_queries(TOP, origins, pa, 4, independent_streams=True)
    bs = run_queries(TOP, origins, pa, 4)
    # deterministic statics identical; sampled means within a few percent
    np.testing.assert_array_equal(bi.n_reached, bs.n_reached)
    np.testing.assert_array_equal(bi.m_bw, bs.m_bw)
    for f in ("m_fw", "b_rt", "response_time_s"):
        a, b = getattr(bi, f).mean(), getattr(bs, f).mean()
        assert abs(a - b) / abs(a) < 0.05, f
    assert bi.accuracy.mean() == bs.accuracy.mean() == 1.0


def test_batch_metrics_summary_and_totals():
    pa = SimParams(seed=1)
    bm = run_queries(TOP, [0, 3], pa, 2)
    s = bm.summary()
    assert s["n_queries"] == 2 and s["n_trials"] == 2
    assert s["mean_total_bytes"] == pytest.approx(
        float(bm.total_bytes.mean()))
    assert (bm.total_messages == bm.m_fw + bm.m_bw + bm.m_rt).all()


# --------------------------------------------------------------------------
# acceptance: one call, >= 10x over the scalar loop
# --------------------------------------------------------------------------

def test_speedup_over_run_query_loop():
    from repro.engine import QuerySpec, SimEngine
    nq, nt = 64, 4
    pa = SimParams(seed=5)
    origins = np.random.default_rng(0).integers(0, TOP.n, nq)
    # the recommended entrypoint: a prepared engine whose NetworkPlan is
    # reused across calls (the legacy run_queries shim rebuilds it)
    engine = SimEngine(TOP, pa)
    spec = QuerySpec(origins=tuple(int(o) for o in origins), n_trials=nt)
    engine.run(spec)                                # warm numpy + plan
    batch_s = min(_timed(lambda: engine.run(spec)) for _ in range(5))

    def loop():
        for q in range(nq):
            for t in range(nt):
                run_query_reference(TOP, int(origins[q]),
                          dataclasses.replace(pa,
                                              seed=pa.seed + q * nt + t))
    loop_s = _timed(loop)
    assert loop_s / batch_s >= 10.0, (
        f"batch {batch_s * 1e3:.0f}ms vs loop {loop_s * 1e3:.0f}ms "
        f"= {loop_s / batch_s:.1f}x")


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


# --------------------------------------------------------------------------
# comm model: closed form == measured from the schedule walk
# --------------------------------------------------------------------------

@pytest.mark.parametrize("schedule", SCHEDULES)
@pytest.mark.parametrize("n_dev", [2, 8, 16])
@pytest.mark.parametrize("k", [1, 20])
def test_fd_comm_model_matches_measured(schedule, n_dev, k):
    n_local = 4096
    assert comm_bytes("fd", n_dev, n_local, k, schedule=schedule) == \
        measure_comm_bytes("fd", n_dev, n_local, k, schedule=schedule)


@pytest.mark.parametrize("algorithm", ["cn", "cn_star"])
@pytest.mark.parametrize("n_dev", [2, 8, 16])
def test_baseline_comm_model_matches_measured(algorithm, n_dev):
    for n_local, k in ((1024, 8), (4096, 20)):
        assert comm_bytes(algorithm, n_dev, n_local, k) == \
            measure_comm_bytes(algorithm, n_dev, n_local, k)


def test_fd_comm_model_vs_simulator_backward_bytes():
    """The p2psim side agrees with the paper's b_bw = k·L·(|P_Q|-1):
    the TPU halving schedule moves the same n-1 lists (Lemma 2)."""
    pa = SimParams(seed=3)
    bm = run_queries(TOP, [0], pa, 1, dynamic=False)
    met = bm.query_metrics(0, 0)
    assert met.m_bw == met.n_reached - 1
    assert met.b_bw == pa.k * 10 * (met.n_reached - 1)
    # TPU halving: n-1 list transfers as well (plus the broadcast term)
    n_dev = 16
    merge_only = measure_comm_bytes("fd", n_dev, 4096, pa.k,
                                    schedule="halving") \
        - (n_dev - 1) * pa.k * 8
    assert merge_only == (n_dev - 1) * pa.k * 8
