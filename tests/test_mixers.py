"""Token-mixer math: flash vs naive attention, RWKV chunked vs scan
(exactness), RG-LRU associative scan vs sequential, MoE capacity vs
ragged, MLA decode vs prefill."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_config, smoke_config
from repro.models import rwkv
from repro.models.attention import flash_attention
from repro.models.griffin import rglru
from repro.models.moe import _moe_local, moe_init


# --------------------------------------------------------------------------
# flash attention vs naive
# --------------------------------------------------------------------------

def naive_attention(q, k, v, causal, window=0, q_offset=0):
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    s *= d ** -0.5
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None]
    if window:
        mask &= (qpos[:, None] - kpos[None]) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, hq, -1)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (5, 1)])
def test_flash_vs_naive(causal, hq, hkv):
    key = jax.random.PRNGKey(0)
    b, sq, sk, d = 2, 75, 75, 16
    q = jax.random.normal(key, (b, sq, hq, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, sk, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, sk, hkv, d))
    out = flash_attention(q, k, v, causal=causal, q_block=32, kv_block=32)
    ref = naive_attention(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_sliding_window():
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 64, 2, 8))
    k = jax.random.normal(jax.random.PRNGKey(4), (1, 64, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(5), (1, 64, 2, 8))
    out = flash_attention(q, k, v, causal=True, window=16,
                          q_block=16, kv_block=16)
    ref = naive_attention(q, k, v, True, window=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(sq=st.integers(3, 40), sk=st.integers(3, 40), seed=st.integers(0, 9))
def test_flash_ragged_shapes(sq, sk, seed):
    q = jax.random.normal(jax.random.PRNGKey(seed), (1, sq, 2, 8))
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, sk, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (1, sk, 2, 8))
    out = flash_attention(q, k, v, causal=False, q_block=16, kv_block=16)
    ref = naive_attention(q, k, v, False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)


# --------------------------------------------------------------------------
# RWKV-6: chunked evaluation is EXACT vs the token recurrence
# --------------------------------------------------------------------------

@pytest.mark.parametrize("t,chunk", [(13, 4), (32, 8), (17, 16), (16, 16)])
def test_rwkv_chunked_exact(t, chunk):
    b, h, kdim, vdim = 2, 3, 8, 8
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, t, h, kdim))
    k = jax.random.normal(ks[1], (b, t, h, kdim))
    v = jax.random.normal(ks[2], (b, t, h, vdim))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, kdim))) * 0.5 + 0.4
    u = jax.random.normal(ks[4], (h, kdim)) * 0.1
    s0 = jnp.zeros((b, h, kdim, vdim))
    o1, s1 = rwkv.rwkv6_scan(r, k, v, w, u, s0)
    o2, s2 = rwkv.rwkv6_chunked(r, k, v, w, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=1e-4, rtol=1e-4)


def test_rwkv_state_carry_split():
    """Evaluating [0:t1] then [t1:t] with carried state == full pass."""
    b, t, h, kdim = 1, 24, 2, 8
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, t, h, kdim))
    k = jax.random.normal(ks[1], (b, t, h, kdim))
    v = jax.random.normal(ks[2], (b, t, h, kdim))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, kdim))) * 0.5 + 0.4
    u = jax.random.normal(ks[4], (h, kdim)) * 0.1
    s0 = jnp.zeros((b, h, kdim, kdim))
    o_full, s_full = rwkv.rwkv6_chunked(r, k, v, w, u, s0, chunk=8)
    t1 = 10
    o1, s_mid = rwkv.rwkv6_chunked(r[:, :t1], k[:, :t1], v[:, :t1],
                                   w[:, :t1], u, s0, chunk=8)
    o2, s_end = rwkv.rwkv6_chunked(r[:, t1:], k[:, t1:], v[:, t1:],
                                   w[:, t1:], u, s_mid, chunk=8)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)),
                               np.asarray(o_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_end), np.asarray(s_full),
                               atol=1e-4)


# --------------------------------------------------------------------------
# RG-LRU: associative scan vs sequential reference
# --------------------------------------------------------------------------

def test_rglru_assoc_vs_sequential():
    b, s, dim = 2, 33, 16
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (b, s, dim))
    a_g = jax.random.normal(jax.random.PRNGKey(1), (b, s, dim))
    i_g = jax.random.normal(jax.random.PRNGKey(2), (b, s, dim))
    lam = jnp.linspace(0.1, 2.0, dim)
    h0 = jax.random.normal(jax.random.PRNGKey(3), (b, dim))
    h, h_last = rglru(x, a_g, i_g, lam, h0)
    # sequential oracle
    r = jax.nn.sigmoid(a_g)
    ig = jax.nn.sigmoid(i_g)
    log_a = -8.0 * jax.nn.softplus(lam)[None, None] * r
    a = jnp.exp(log_a)
    gated = x * ig * jnp.sqrt(jnp.maximum(1 - jnp.exp(2 * log_a), 1e-12))
    hs = []
    hc = h0
    for t in range(s):
        hc = a[:, t] * hc + gated[:, t]
        hs.append(hc)
    ref = jnp.stack(hs, axis=1)
    np.testing.assert_allclose(np.asarray(h), np.asarray(ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(ref[:, -1]),
                               atol=1e-4)


# --------------------------------------------------------------------------
# MoE: capacity dispatch == ragged grouped GEMM when capacity is ample
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["granite-moe-1b-a400m",
                                  "moonshot-v1-16b-a3b"])
def test_moe_capacity_vs_ragged(arch):
    cfg = smoke_config(get_config(arch))
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y1, a1 = _moe_local(params, x, cfg, impl="capacity")
    y2, a2 = _moe_local(params, x, cfg, impl="ragged")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=3e-5)
    assert float(a1) == pytest.approx(float(a2))


def test_moe_capacity_drops_overflow():
    """With capacity_factor << 1 the output degrades but stays finite."""
    cfg = smoke_config(get_config("granite-moe-1b-a400m"))
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    params = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, _ = _moe_local(params, x, cfg, impl="capacity")
    assert bool(jnp.all(jnp.isfinite(y)))
