"""Score-list merge kernel (bitonic, Merge-and-Backward) vs oracle."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.scorelist import empty_scorelist
from repro.kernels.merge import merge_pallas, merge_ref
from repro.kernels.topk import topk_ref


def _mk_list(key, shape, k):
    x = jax.random.normal(key, shape + (4 * k,))
    return topk_ref(x, k)


@pytest.mark.parametrize("k", [1, 4, 7, 16, 20, 64])
@pytest.mark.parametrize("lead", [(), (3,), (2, 5)])
def test_merge_matches_ref(k, lead):
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    va, ia = _mk_list(ka, lead, k)
    vb, ib = _mk_list(kb, lead, k)
    v1, i1 = merge_pallas(va, ia, vb, ib)
    v2, i2 = merge_ref(va, ia, vb, ib)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2))
    # indices may differ only on tied values
    same = np.asarray(v1) == np.asarray(v2)
    assert same.all()


def test_merge_identity():
    """empty list is the identity element of merge."""
    v, i = _mk_list(jax.random.PRNGKey(1), (), 8)
    ev, ei = empty_scorelist((), 8)
    mv, mi = merge_pallas(v, i, ev, ei)
    np.testing.assert_allclose(np.asarray(mv), np.asarray(v))
    np.testing.assert_array_equal(np.asarray(mi), np.asarray(i))


@settings(max_examples=25, deadline=None)
@given(k=st.integers(1, 32), seed=st.integers(0, 999))
def test_merge_commutative_and_topk_of_union(k, seed):
    ka, kb = jax.random.split(jax.random.PRNGKey(seed))
    va, ia = _mk_list(ka, (), k)
    vb, ib = _mk_list(kb, (), k)
    v1, _ = merge_pallas(va, ia, vb, ib)
    v2, _ = merge_pallas(vb, ib, va, ia)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2))
    # merge == top-k of the concatenated union
    union = np.concatenate([np.asarray(va), np.asarray(vb)])
    np.testing.assert_allclose(np.asarray(v1), np.sort(union)[::-1][:k],
                               rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(k=st.integers(1, 16), seed=st.integers(0, 99))
def test_merge_associative(k, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    lists = [_mk_list(kk, (), k) for kk in ks]
    (va, ia), (vb, ib), (vc, ic) = lists
    l1 = merge_pallas(*merge_pallas(va, ia, vb, ib), vc, ic)
    l2 = merge_pallas(va, ia, *merge_pallas(vb, ib, vc, ic))
    np.testing.assert_allclose(np.asarray(l1[0]), np.asarray(l2[0]))


@pytest.mark.parametrize("k", [4, 8, 20])
def test_merge_valid_masks_match_premasked(k):
    """valid_a/valid_b row masks (churned-out peers) == pre-masking the
    input values to -inf, on the jnp oracle AND inside the Pallas
    kernel — and an invalid list is absorbed like the empty list."""
    ka, kb = jax.random.split(jax.random.PRNGKey(7))
    lead = (3, 5)
    va, ia = _mk_list(ka, lead, k)
    vb, ib = _mk_list(kb, lead, k)
    rng = np.random.default_rng(0)
    ma = rng.random(lead) < 0.5
    mb = rng.random(lead) < 0.5
    va_m = np.where(ma[..., None], np.asarray(va), -np.inf).astype(va.dtype)
    vb_m = np.where(mb[..., None], np.asarray(vb), -np.inf).astype(vb.dtype)
    for fn in (merge_ref, merge_pallas):
        v1, i1 = fn(va, ia, vb, ib, valid_a=ma, valid_b=mb)
        v2, i2 = fn(va_m, ia, vb_m, ib)
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    # one-sided mask, fully-valid rows: a no-op vs the unmasked merge
    ones = np.ones(lead, bool)
    v3, _ = merge_pallas(va, ia, vb, ib, valid_b=ones)
    v0, _ = merge_pallas(va, ia, vb, ib)
    np.testing.assert_array_equal(np.asarray(v3), np.asarray(v0))
    # an all-invalid b behaves like merging with the empty list
    v4, _ = merge_pallas(va, ia, vb, ib, valid_b=~ones)
    np.testing.assert_array_equal(np.asarray(v4), np.asarray(va))


def test_merge_float64_passthrough():
    """float64 lists (the x64 simulator sweep) merge in float64 on both
    the Pallas kernel and the jnp oracle — no silent f32 downcast."""
    from repro import jaxcompat
    with jaxcompat.enable_x64():
        rng = np.random.default_rng(0)
        va = np.sort(rng.random((4, 8)))[:, ::-1].copy()
        vb = np.sort(rng.random((4, 8)))[:, ::-1].copy()
        ia = rng.integers(0, 99, (4, 8)).astype(np.int32)
        ib = rng.integers(0, 99, (4, 8)).astype(np.int32)
        v1, i1 = merge_pallas(va, ia, vb, ib)
        v2, i2 = merge_ref(va, ia, vb, ib)
        assert v1.dtype == v2.dtype == np.float64
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
        # exact top-k of the union, descending, in full precision
        both = np.concatenate([va, vb], axis=1)
        np.testing.assert_array_equal(
            np.asarray(v1), np.sort(both, axis=1)[:, ::-1][:, :8])
    # f32 inputs keep the historical f32 compute dtype
    v3, _ = merge_ref(va.astype(np.float32), ia, vb.astype(np.float32), ib)
    assert v3.dtype == np.float32
