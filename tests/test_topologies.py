"""Topology suite (ISSUE 5): registry surface, generator invariants,
hierarchical auto-TTL agreement, and per-edge latency-model parity
across the scalar reference and BOTH SimEngine backends.

The parity contract extends the engine's existing one: with
``latency_model="edge"`` (BRITE distance-proportional link latencies
from the topology's embedding) every backend still reproduces
``run_query_reference`` bit-for-bit in every RNG mode — the
deterministic latencies ride inside the SAME shared draw arrays, so
nothing about the cross-backend story changes.
"""
import dataclasses

import numpy as np
import pytest

from repro.engine import NetworkPlan, QuerySpec, SimEngine, get_policy
from repro.p2psim import (SimParams, TopologySpec, available_topologies,
                          barabasi_albert, build_topology, get_topology,
                          register_topology, run_query_reference)
from repro.p2psim.graph import (as_csr, bfs_tree, directed_edges,
                                eccentricity_ttl)

ALL_FAMILIES = ("ba", "waxman", "hierarchical", "gnutella",
                "small-world", "random-regular")

# one shared hierarchical overlay for the engine-parity tests (small:
# keeps the per-tree jit compiles fast)
HTOP = build_topology("hierarchical", 260, seed=3)
PA_EDGE = SimParams(seed=11, latency_model="edge")

_PARITY_FIELDS = ("n_reached", "n_edges_pq", "m_fw", "m_bw", "m_rt",
                  "b_fw", "b_bw", "b_rt", "response_time_s", "accuracy")


def _legacy_kwargs(pol):
    import math
    kw = dict(algorithm=pol.algorithm, strategy=pol.strategy,
              dynamic=pol.dynamic)
    if not math.isinf(pol.lifetime_mean_s):
        kw["lifetime_mean_s"] = pol.lifetime_mean_s
    return kw


# --------------------------------------------------------------------------
# registry surface
# --------------------------------------------------------------------------

def test_registry_surface():
    assert set(available_topologies()) >= set(ALL_FAMILIES)
    with pytest.raises(KeyError):
        get_topology("torus-nope")
    with pytest.raises(ValueError):
        register_topology(TopologySpec("ba", barabasi_albert, regime=""))
    spec = get_topology("hierarchical")
    assert get_topology(spec) is spec         # spec passes through
    assert "BRITE" in spec.regime
    # defaults merge with overrides
    top = build_topology("random-regular", 30, seed=1, d=6)
    assert (top.degree() == 6).all()


# --------------------------------------------------------------------------
# generator invariants: connectivity, simplicity, embedding
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALL_FAMILIES)
def test_family_connected_and_simple(name):
    for seed in (0, 7):
        n = 150 if name == "waxman" else 400
        top = build_topology(name, n, seed=seed)
        assert top.n == n and top.kind == name
        _, _, reached = bfs_tree(top, 0, top.n)
        assert reached.all(), f"{name} seed={seed} disconnected"
        for u in range(top.n):
            nb = top.neighbors[u]
            assert len(np.unique(nb)) == len(nb)          # no multi-edges
            assert u not in nb                            # no self-loops
            assert all(u in top.neighbors[int(v)] for v in nb)  # symmetric
        if name == "ba":
            assert top.coords is None     # flat BA has no embedding
        else:
            assert top.coords is not None and top.coords.shape == (n, 2)


@pytest.mark.parametrize("name", ALL_FAMILIES)
def test_family_degree_distribution(name):
    n = 150 if name == "waxman" else 500
    top = build_topology(name, n, seed=7)
    degs = top.degree()
    assert 2.0 < top.avg_degree() < 8.0       # paper regime: d(G) ~ 4
    if name in ("ba", "gnutella", "hierarchical"):
        # power-law core: heavy tail far above the mean
        assert degs.max() >= 3 * top.avg_degree(), name
    if name == "small-world":
        assert degs.max() <= 4 + 6            # lattice + few rewires
    if name == "random-regular":
        assert (degs == 4).all()              # exactly d-regular


def test_random_regular_validation():
    with pytest.raises(ValueError):
        build_topology("random-regular", 30, d=3)       # odd d
    with pytest.raises(ValueError):
        build_topology("random-regular", 4, d=4)        # n <= d


def test_hierarchical_structure():
    top = build_topology("hierarchical", 600, seed=5, n_as=6)
    assert (top.coords >= 0).all() and (top.coords <= 1).all()
    # two-level latency structure: plenty of short intra-AS links AND
    # some long inter-AS gateway links
    indptr, indices = as_csr(top)
    lat = top.edge_latencies(*directed_edges(indptr, indices))
    assert np.median(lat) < 0.08              # intra-AS dominates
    assert lat.max() > 0.10                   # gateways span ASes


# --------------------------------------------------------------------------
# auto-TTL agreement on hierarchical graphs (plan vs scalar path)
# --------------------------------------------------------------------------

def test_hierarchical_auto_ttl_plan_vs_scalar_agreement():
    for top in (HTOP, build_topology("hierarchical", 500, seed=9)):
        plan = NetworkPlan(top)
        for origin in (0, top.n // 2, top.n - 1):
            assert plan.auto_ttl(origin) == eccentricity_ttl(top, origin)
        sts, _ = plan.origin_statics(np.array([0, top.n - 1]), 0, "st1+2")
        assert sts[0].ttl == plan.auto_ttl(0)
        assert sts[1].ttl == plan.auto_ttl(top.n - 1)


# --------------------------------------------------------------------------
# per-edge latency model: values + plan plumbing
# --------------------------------------------------------------------------

def test_pair_latency_formula_and_plan_alignment():
    top = HTOP
    u, v = 0, int(top.neighbors[0][0])
    d = float(np.sqrt(((top.coords[u] - top.coords[v]) ** 2).sum()))
    assert top.pair_latency(u, v) == top.lat_base_s + top.lat_scale_s * d
    # NetworkPlan.edge_lat is aligned with the directed edge arrays
    plan = NetworkPlan(top)
    assert plan.edge_lat is not None
    np.testing.assert_array_equal(
        plan.edge_lat, top.pair_latency(plan.e_src, plan.e_dst))
    # ... and the per-origin gather holds the tree-edge latency
    sts, _ = plan.origin_statics(np.array([0]), 0, "st1+2")
    st = sts[0]
    child = int(st.idx[st.parent[st.idx] >= 0][0])
    assert st.par_lat[child] == top.pair_latency(child,
                                                 int(st.parent[child]))
    # embeddings-free topologies have no latency arrays
    assert NetworkPlan(barabasi_albert(40)).edge_lat is None
    with pytest.raises(ValueError):
        barabasi_albert(40).pair_latency(0, 1)


# --------------------------------------------------------------------------
# latency-model parity: reference == numpy == jax, every RNG mode
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name,lifetime", [
    ("fd-st1+2", None), ("fd-dynamic", None), ("cn-star", None),
    ("fd-dynamic", 25.0),                     # churn draws shift position
])
def test_edge_latency_parity_all_backends(name, lifetime):
    """With latency_model="edge", both engine backends reproduce the
    scalar reference bit-for-bit (shared batch of one, independent
    streams) and each other (shared stream, batch > 1)."""
    pol = get_policy(name)
    if lifetime is not None:
        pol = pol.variant(lifetime_mean_s=lifetime)
    kw = _legacy_kwargs(pol)
    plan = NetworkPlan(HTOP)
    en = SimEngine(plan, PA_EDGE)
    ej = SimEngine(plan, PA_EDGE, backend="jax")
    # shared batch of one == scalar reference
    met, _ = run_query_reference(HTOP, 5, dataclasses.replace(
        PA_EDGE, seed=2), **kw)
    for eng in (en, ej):
        res = eng.run(QuerySpec(origins=(5,), seed=2), pol)
        assert res.query_metrics(0, 0) == met, eng.backend
        assert res.topology == "hierarchical"
        assert res.latency_model == "edge"
    # independent streams: entry-wise reference parity
    spec = QuerySpec(origins=(0, 7), n_trials=2, rng="independent")
    rn, rj = en.run(spec, pol), ej.run(spec, pol)
    assert rj.backend_used == "sim-jax"
    for q, o in enumerate((0, 7)):
        for t in range(2):
            met, _ = run_query_reference(
                HTOP, o,
                dataclasses.replace(PA_EDGE, seed=PA_EDGE.seed + q * 2 + t),
                **kw)
            assert rn.query_metrics(q, t) == met, (name, "numpy", q, t)
            assert rj.query_metrics(q, t) == met, (name, "jax", q, t)
    # shared stream, batch > 1: full cross-backend equality
    spec = QuerySpec(origins=(1, 8), n_trials=3)
    ra, rb = en.run(spec, pol).metrics, ej.run(spec, pol).metrics
    for f in _PARITY_FIELDS:
        np.testing.assert_array_equal(getattr(ra, f), getattr(rb, f),
                                      err_msg=f"{name}: {f}")


@pytest.mark.parametrize("family", ("ba", "small-world",
                                    "random-regular", "gnutella",
                                    "waxman"))
def test_every_family_through_both_backends(family):
    """Acceptance: EVERY registered family runs through the numpy AND
    jax backends with entry-wise identical metrics in every RNG mode,
    under its native latency model ("iid" for embedding-free flat BA;
    the hierarchical family is covered exhaustively above)."""
    n = 120 if family == "waxman" else 200
    top = build_topology(family, n, seed=4)
    lm = "iid" if top.coords is None else "edge"
    pa = SimParams(seed=11, latency_model=lm)
    plan = NetworkPlan(top)
    en = SimEngine(plan, pa)
    ej = SimEngine(plan, pa, backend="jax")
    # shared batch of one: backends == scalar reference
    met, _ = run_query_reference(top, 1, pa)
    for eng in (en, ej):
        res = eng.run(QuerySpec(origins=(1,)))
        assert res.query_metrics(0, 0) == met, eng.backend
        assert res.topology == family and res.latency_model == lm
    # independent streams AND shared batch > 1: numpy == jax entrywise
    for spec in (QuerySpec(origins=(0, 1), n_trials=2,
                           rng="independent"),
                 QuerySpec(origins=(0, 1), n_trials=2)):
        rn, rj = en.run(spec), ej.run(spec)
        assert rj.backend_used == "sim-jax"
        for f in _PARITY_FIELDS:
            np.testing.assert_array_equal(
                getattr(rn.metrics, f), getattr(rj.metrics, f),
                err_msg=f"{family}/{spec.rng}: {f}")


def test_latency_model_validation_and_result_fields():
    with pytest.raises(ValueError):
        QuerySpec(latency_model="gaussian")
    with pytest.raises(ValueError):
        run_query_reference(barabasi_albert(40),
                            params=SimParams(latency_model="nope"))
    # an invalid model smuggled in via SimParams is rejected by the
    # engine too — never silently run as iid
    with pytest.raises(ValueError):
        SimEngine(HTOP, SimParams(latency_model="Edge")).run(QuerySpec())
    # edge mode demands an embedding, at both entry points
    ba = barabasi_albert(60, seed=1)
    with pytest.raises(ValueError):
        run_query_reference(ba, params=SimParams(latency_model="edge"))
    with pytest.raises(ValueError):
        SimEngine(ba).run(QuerySpec(origins=(0,), latency_model="edge"))
    # the iid default is recorded too, and the models actually differ
    r_iid = SimEngine(HTOP, SimParams(seed=11)).run(QuerySpec(origins=(0,)))
    assert r_iid.topology == "hierarchical"
    assert r_iid.latency_model == "iid"
    r_edge = SimEngine(HTOP, PA_EDGE).run(QuerySpec(origins=(0,)))
    assert (r_iid.metrics.response_time_s[0, 0]
            != r_edge.metrics.response_time_s[0, 0])
    s = r_edge.summary()
    assert s["topology"] == "hierarchical" and s["latency_model"] == "edge"
    # the QuerySpec override beats the engine's SimParams
    r = SimEngine(HTOP, SimParams(seed=11)).run(
        QuerySpec(origins=(0,), latency_model="edge"))
    assert r.latency_model == "edge"
    assert (r.metrics.response_time_s[0, 0]
            == r_edge.metrics.response_time_s[0, 0])


def test_edge_latency_fd_stats_policy():
    """The two-round fd-stats heuristic threads the latency model
    through both reference rounds."""
    res = SimEngine(HTOP, PA_EDGE).run(QuerySpec(origins=(0,)), "fd-stats")
    assert res.latency_model == "edge" and res.topology == "hierarchical"
    assert res.extras["comm_reduction"] > 0.0
