"""Property-based backend parity: random overlays × policies × rng.

Two contracts, drawn over random small Barabási–Albert overlays:

* **f64 (bit-exactness)** — for every policy and both rng modes the
  jax sweep reproduces the numpy reference's per-entry metrics BIT FOR
  BIT (``fd-stats`` has no jax path and must *report* its numpy
  fallback rather than silently diverge).
* **f32 / bf16 (tolerance)** — the reduced-precision jax sweep is
  validated against its own f64 rerun by the recorded tolerance
  report: recall@k == 1.0 whenever the f64 scores are well separated
  at the k boundary (``separated``), and the positional score rtol
  within the per-precision bound always.  On ties / sub-spacing gaps
  (bf16 near 1.0 has spacing ~0.004, so U(0,1) top scores collapse)
  owner sets may legitimately differ — the contract's ``ok`` bit is
  the asserted invariant, never raw recall.

Runs under real hypothesis in CI (``--hypothesis-profile=ci``,
derandomized) and under the deterministic conftest stub when the
package is absent.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.engine import SimEngine
from repro.engine.api import QuerySpec, available_policies
from repro.engine.precision import PRECISION_RTOL
from repro.p2psim.graph import barabasi_albert
from repro.p2psim.simulate import SimParams

POLICIES = ("fd-basic", "fd-st1", "fd-st1+2", "fd-dynamic",
            "cn", "cn-star", "fd-stats")
RNG_MODES = ("shared", "independent")
_METRIC_FIELDS = ("m_fw", "m_bw", "m_rt", "b_fw", "b_bw", "b_rt",
                  "response_time_s", "accuracy")


def _engines(n, m, seed, **kw):
    top = barabasi_albert(n, m, seed=seed)
    params = SimParams(k=4, seed=seed + 1)
    return (SimEngine(top, params, backend="numpy"),
            SimEngine(top, params, backend="jax", **kw))


def test_policy_registry_is_covered():
    """The property sweep really does cover every registered policy."""
    assert sorted(POLICIES) == sorted(available_policies())


@settings(max_examples=8, deadline=None)
@given(n=st.integers(12, 40), m=st.integers(1, 3),
       seed=st.integers(0, 10_000),
       pol=st.integers(0, len(POLICIES) - 1),
       rng=st.integers(0, len(RNG_MODES) - 1))
def test_f64_jax_matches_numpy_bits(n, m, seed, pol, rng):
    policy, mode = POLICIES[pol], RNG_MODES[rng]
    np_eng, jx_eng = _engines(n, max(1, min(m, n - 1)), seed)
    if policy == "fd-stats":             # one origin x one trial per call
        spec = QuerySpec(origins=(0,), rng=mode)
    else:
        spec = QuerySpec(origins=(0, n // 2), n_trials=2, rng=mode)
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        r_np = np_eng.run(spec, policy)
        r_jx = jx_eng.run(spec, policy)
    if policy == "fd-stats":             # no jax path: visible fallback
        assert r_jx.backend_used == "sim"
    for f in _METRIC_FIELDS:
        np.testing.assert_array_equal(
            getattr(r_np.metrics, f), getattr(r_jx.metrics, f),
            err_msg=f"{policy}/{mode}: {f}")


@settings(max_examples=6, deadline=None)
@given(n=st.integers(12, 32), seed=st.integers(0, 10_000),
       pol=st.integers(0, len(POLICIES) - 2),   # fd-stats raises: below
       rng=st.integers(0, len(RNG_MODES) - 1),
       prec=st.integers(0, 1))
def test_reduced_precision_tolerance_contract(n, seed, pol, rng, prec):
    policy, mode = POLICIES[pol], RNG_MODES[rng]
    precision = ("f32", "bf16")[prec]
    _, eng = _engines(n, 2, seed, precision=precision)
    res = eng.run(QuerySpec(origins=(0,), n_trials=2, rng=mode), policy)
    assert res.precision == precision
    tol = res.extras["tolerance"]
    assert tol["ok"], f"{policy}/{mode}/{precision}: {tol}"
    assert tol["max_rtol"] <= PRECISION_RTOL[precision]
    if tol["separated"]:
        assert tol["recall"] == 1.0


def test_fd_stats_rejects_reduced_precision():
    _, eng = _engines(16, 2, 0, precision="f32")
    with pytest.raises(ValueError, match="fd-stats"):
        eng.run(QuerySpec(origins=(0,)), "fd-stats")
