"""Optimizer, checkpointing (atomic/keep-N/resume), elastic resharding,
fault-tolerance driver, data pipeline determinism, compression."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager, latest_step, restore, save
from repro.data.pipeline import SyntheticLM
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_lr
from repro.optim.compress import topk_sparsify
from repro.runtime.ft import (FailureInjector, StragglerTimeout,
                              StragglerWatchdog, run_with_recovery)


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, grad_clip=1e9)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params, cfg)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}           # d/dw ||w||^2
        params, opt, m = adamw_update(grads, opt, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_cosine_schedule():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      min_lr_ratio=0.1)
    assert float(cosine_lr(cfg, jnp.asarray(0))) == 0.0
    assert float(cosine_lr(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(cosine_lr(cfg, jnp.asarray(110))) == pytest.approx(0.1)


def test_grad_clip_applied():
    cfg = AdamWConfig(lr=0.0, grad_clip=1.0)
    params = {"w": jnp.ones((4,))}
    opt = adamw_init(params, cfg)
    _, _, metrics = adamw_update({"w": jnp.full((4,), 100.0)}, opt, params,
                                 cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 7, t)
    assert latest_step(str(tmp_path)) == 7
    got = restore(str(tmp_path), 7, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t))
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(t["a"]))
    np.testing.assert_array_equal(np.asarray(got["b"]["c"]),
                                  np.asarray(t["b"]["c"]))


def test_keep_n_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep=2,
                            blocking=False)
    for s in range(1, 6):
        mgr.maybe_save(s, _tree())
    mgr.wait()
    steps = sorted(int(n[5:]) for n in os.listdir(tmp_path)
                   if n.startswith("step_") and not n.endswith(".tmp"))
    assert steps == [4, 5]


def test_restore_latest_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1, blocking=True)
    t = _tree()
    mgr.maybe_save(3, t)
    step, got = mgr.restore_latest(t)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(t["a"]))


def test_shape_mismatch_rejected(tmp_path):
    save(str(tmp_path), 1, {"a": jnp.ones((2,))})
    with pytest.raises(ValueError):
        restore(str(tmp_path), 1, {"a": jax.ShapeDtypeStruct((3,),
                                                             jnp.float32)})


# --------------------------------------------------------------------------
# fault tolerance
# --------------------------------------------------------------------------

def test_watchdog_catches_straggler():
    wd = StragglerWatchdog(timeout_s=0.2)
    with pytest.raises(StragglerTimeout):
        wd.run(lambda: time.sleep(2.0))
    assert wd.run(lambda: 42) == 42


def test_recovery_restores_and_completes(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1, blocking=True)
    inj = FailureInjector(mtbf_steps=4.0, seed=1)
    calls = {"fail": 0}

    def step(i, state):
        if inj.tick():
            calls["fail"] += 1
            raise RuntimeError("simulated pod failure")
        return state + 1

    final = run_with_recovery(
        step, 0, n_steps=20, ckpt_manager=mgr,
        restore_fn=lambda: mgr.restore_latest(0), max_failures=50)
    assert final == 20
    assert calls["fail"] > 0                     # failures actually hit


def test_recovery_gives_up_after_max():
    def step(i, state):
        raise RuntimeError("always fails")
    with pytest.raises(RuntimeError):
        run_with_recovery(step, 0, n_steps=3, max_failures=2)


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------

def test_data_deterministic_and_restartable():
    d1 = SyntheticLM(vocab_size=100, seq_len=32, global_batch=4, seed=5)
    d2 = SyntheticLM(vocab_size=100, seq_len=32, global_batch=4, seed=5)
    b1, b2 = d1.batch_at(17), d2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    assert b1["tokens"].max() < 100


def test_data_learnable_structure():
    d = SyntheticLM(vocab_size=1000, seq_len=64, global_batch=2, seed=0,
                    noise=0.0, motif_len=8)
    b = d.batch_at(0)
    # motif repeats: token[t] == token[t-8] for noise-free stream
    toks = b["tokens"]
    assert (toks[:, 8:] == toks[:, :-8]).mean() > 0.99


# --------------------------------------------------------------------------
# compression local phase
# --------------------------------------------------------------------------

def test_topk_sparsify_conservation():
    g = jax.random.normal(jax.random.PRNGKey(0), (64,))
    ef = jnp.zeros((64,))
    vals, idx, ef2 = topk_sparsify(g, 8, ef)
    dense = jnp.zeros((64,)).at[idx].add(vals)
    np.testing.assert_allclose(np.asarray(dense + ef2), np.asarray(g),
                               atol=1e-6)  # sent + residual == signal
    # selected are the 8 largest |.|
    mags = np.abs(np.asarray(g))
    np.testing.assert_array_equal(np.sort(np.asarray(idx)),
                                  np.sort(np.argsort(mags)[-8:]))


def test_error_feedback_accumulates():
    ef = jnp.zeros((16,))
    g = jnp.ones((16,)) * 0.1
    g = g.at[0].set(10.0)
    _, idx, ef = topk_sparsify(g, 1, ef)
    assert int(idx[0]) == 0
    # small entries accumulate until they win
    for _ in range(3):
        vals, idx, ef = topk_sparsify(jnp.zeros((16,)), 1, ef)
    assert float(jnp.abs(ef).sum()) < float(jnp.abs(g).sum())
