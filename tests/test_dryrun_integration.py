"""Integration: one real dry-run cell compiles on the production meshes
(512 fake host devices, subprocess) and produces coherent roofline
artifacts.  The full 64-cell sweep runs via the CLI; this guards the
machinery in CI time."""
import pytest

from conftest import run_with_devices


@pytest.mark.parametrize("mp", [False, True], ids=["16x16", "2x16x16"])
def test_dryrun_cell_compiles(mp):
    out = run_with_devices(f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.launch.dryrun import run_cell
r = run_cell("qwen1.5-0.5b", "train_4k", multi_pod={mp}, verbose=False)
assert not r.get("error") and not r.get("skipped"), r
assert r["flops"] > 0 and r["hlo_bytes"] > 0
assert r["collective"]["total"] > 0
assert r["roofline"]["dominant"] in ("compute_s", "memory_s",
                                     "collective_s")
assert r["memory"]["per_device_total_gib"] < 16.0   # fits v5e HBM
print("CELL_OK", json.dumps(r["roofline"]["dominant"]))
""", n_devices=512, timeout=420)
    assert "CELL_OK" in out


def test_dryrun_skips_long_context_for_full_attention():
    out = run_with_devices("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import run_cell
r = run_cell("phi3-medium-14b", "long_500k", verbose=False)
assert r["skipped"], r
r2 = run_cell("rwkv6-3b", "long_500k", verbose=False)
assert not r2.get("skipped") and not r2.get("error"), r2
print("SKIP_RULES_OK")
""", n_devices=512, timeout=420)
    assert "SKIP_RULES_OK" in out
