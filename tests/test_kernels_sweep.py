"""Forward-sweep kernels (level arrivals, Appendix-A wait) vs oracles.

Mirrors test_kernels_merge.py for the gather/wait-propagation hot loop:
the Pallas kernels run in interpret mode on CPU and must reproduce the
jnp oracles bit for bit in f64, preserve f32 / bf16 dtypes (no silent
upcast), and handle the churn-fused send variant's validity masking
(dead rows send at +inf).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import jaxcompat
from repro.kernels.sweep import level_arrivals, wait_propagate
from repro.kernels.sweep.ref import arrivals_ref, wait_ref
from repro.kernels.sweep.sweep import arrivals_pallas, wait_pallas


def _arrival_inputs(rng, E, L, Lp, dtype):
    tq_prev = rng.random((E, Lp)).astype(dtype)
    dn = rng.random((E, L)).astype(dtype)
    par_pos = rng.integers(0, Lp, L).astype(np.int64)
    return tq_prev, dn, par_pos


def _wait_inputs(rng, E, L, dtype):
    own = rng.random((E, L)).astype(dtype)
    all_in = rng.random((E, L)).astype(dtype)
    deadline = rng.random((E, L)).astype(dtype)
    return own, all_in, deadline


@pytest.mark.parametrize("E,L,Lp", [(1, 1, 1), (3, 7, 4), (8, 33, 17)])
def test_arrivals_pallas_matches_ref_f64(E, L, Lp):
    with jaxcompat.enable_x64():
        rng = np.random.default_rng(0)
        tq_prev, dn, par_pos = _arrival_inputs(rng, E, L, Lp, np.float64)
        a1 = np.asarray(arrivals_pallas(tq_prev, dn, par_pos,
                                        interpret=True))
        a2 = np.asarray(arrivals_ref(tq_prev, dn, par_pos))
        assert a1.dtype == a2.dtype == np.float64
        np.testing.assert_array_equal(a1, a2)
        # and vs the raw numpy expression (the scalar reference's bits)
        np.testing.assert_array_equal(a2, tq_prev[:, par_pos] + dn)


@pytest.mark.parametrize("E,L", [(1, 1), (4, 9), (6, 40)])
def test_wait_pallas_matches_ref_f64(E, L):
    with jaxcompat.enable_x64():
        rng = np.random.default_rng(1)
        own, all_in, deadline = _wait_inputs(rng, E, L, np.float64)
        s1 = np.asarray(wait_pallas(own, all_in, deadline, None,
                                    interpret=True))
        s2 = np.asarray(wait_ref(own, all_in, deadline))
        assert s1.dtype == s2.dtype == np.float64
        np.testing.assert_array_equal(s1, s2)
        np.testing.assert_array_equal(
            s2, np.minimum(np.maximum(own, all_in),
                           np.maximum(deadline, own)))


@pytest.mark.parametrize("dtype", [np.float64, np.float32, "bfloat16"])
def test_sweep_kernels_preserve_dtype(dtype):
    """f64 / f32 / bf16 inputs come back in the same dtype on both the
    oracle and the Pallas interpret path — no silent upcast."""
    import jax.numpy as jnp
    dt = jnp.dtype(dtype)
    with jaxcompat.enable_x64():
        rng = np.random.default_rng(2)
        tq_prev, dn, par_pos = _arrival_inputs(rng, 3, 5, 4, np.float64)
        tq_prev = jnp.asarray(tq_prev, dt)
        dn = jnp.asarray(dn, dt)
        for use_pallas in (False, True):
            a = level_arrivals(tq_prev, dn, par_pos,
                               use_pallas=use_pallas, interpret=True)
            assert a.dtype == dt
        own, all_in, deadline = (jnp.asarray(x, dt) for x in
                                 _wait_inputs(rng, 3, 5, np.float64))
        death = jnp.asarray(rng.random((3, 5)), dt)
        for use_pallas in (False, True):
            s = wait_propagate(own, all_in, deadline,
                               use_pallas=use_pallas, interpret=True)
            assert s.dtype == dt
            s2, snd = wait_propagate(own, all_in, deadline, death=death,
                                     use_pallas=use_pallas,
                                     interpret=True)
            assert s2.dtype == dt and snd.dtype == dt


def test_wait_churn_send_masks_dead_rows():
    """The fused churn variant: ``send = s`` exactly where the peer is
    still alive at its send time (``death >= s``) and +inf elsewhere —
    identical between oracle and Pallas, and to masking by hand."""
    with jaxcompat.enable_x64():
        rng = np.random.default_rng(3)
        own, all_in, deadline = _wait_inputs(rng, 5, 11, np.float64)
        death = rng.random((5, 11))
        s_ref, snd_ref = wait_propagate(own, all_in, deadline,
                                        death=death, use_pallas=False)
        s_pl, snd_pl = wait_pallas(own, all_in, deadline, death,
                                   interpret=True)
        np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_pl))
        np.testing.assert_array_equal(np.asarray(snd_ref),
                                      np.asarray(snd_pl))
        alive = death >= np.asarray(s_ref)
        np.testing.assert_array_equal(
            np.asarray(snd_ref),
            np.where(alive, np.asarray(s_ref), np.inf))
        assert not alive.all() and alive.any()   # both branches hit


@settings(max_examples=20, deadline=None)
@given(E=st.integers(1, 6), L=st.integers(1, 24), Lp=st.integers(1, 24),
       seed=st.integers(0, 999))
def test_sweep_kernels_property_parity(E, L, Lp, seed):
    """Random shapes: Pallas interpret == jnp oracle, bit for bit, for
    both kernels (f64) including the churn send."""
    with jaxcompat.enable_x64():
        rng = np.random.default_rng(seed)
        tq_prev, dn, par_pos = _arrival_inputs(rng, E, L, Lp, np.float64)
        np.testing.assert_array_equal(
            np.asarray(arrivals_pallas(tq_prev, dn, par_pos,
                                       interpret=True)),
            np.asarray(arrivals_ref(tq_prev, dn, par_pos)))
        own, all_in, deadline = _wait_inputs(rng, E, L, np.float64)
        death = rng.random((E, L))
        s1, snd1 = wait_pallas(own, all_in, deadline, death,
                               interpret=True)
        s2, snd2 = wait_propagate(own, all_in, deadline, death=death,
                                  use_pallas=False)
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
        np.testing.assert_array_equal(np.asarray(snd1), np.asarray(snd2))
