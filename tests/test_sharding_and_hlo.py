"""Partition-rule invariants (every assigned axis divides the dim), the
HLO analyzer calibration, topology schedules, and elastic resharding."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config, list_archs
from repro.core import topology
from repro.models import model as M
from repro.optim.sharding import param_specs
from repro.roofline.hlo_parse import analyze


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESHES = [FakeMesh({"data": 16, "model": 16}),
          FakeMesh({"pod": 2, "data": 16, "model": 16})]


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("mesh", MESHES, ids=["sp", "mp"])
def test_param_specs_divisibility(arch, mesh):
    """INVARIANT: every sharded dim divides the product of its axes."""
    cfg = get_config(arch)
    params_abs = jax.eval_shape(
        lambda k: M.init_params(k, cfg, max_seq=4096),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = param_specs(params_abs, cfg, mesh)
    flat_p = jax.tree.leaves(params_abs)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "index"))
    import jax.tree_util as jtu
    specs_flat = jtu.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
    )[0]
    assert len(flat_p) == len(specs_flat)
    n_sharded = 0
    for leaf, spec in zip(flat_p, specs_flat):
        for d, ax in enumerate(spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            assert leaf.shape[d] % size == 0, (arch, leaf.shape, spec)
            n_sharded += 1
    # most parameters must actually be sharded (ZeRO/TP coverage)
    assert n_sharded >= 0.5 * len(flat_p), (arch, n_sharded, len(flat_p))


def test_embedding_is_vocab_sharded():
    cfg = get_config("qwen2-0.5b")
    params_abs = jax.eval_shape(
        lambda k: M.init_params(k, cfg, max_seq=128),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = param_specs(params_abs, cfg, FakeMesh({"data": 16, "model": 16}))
    assert specs["embed"][0] == "model"          # FD's sharded score axis


# --------------------------------------------------------------------------
# HLO analyzer calibration (the dry-run's measurement instrument)
# --------------------------------------------------------------------------

def test_hlo_plain_dot():
    M_, N_, K_ = 128, 64, 32
    x = jax.ShapeDtypeStruct((M_, K_), jnp.float32)
    w = jax.ShapeDtypeStruct((K_, N_), jnp.float32)
    t = analyze(jax.jit(lambda a, b: a @ b).lower(x, w).compile().as_text())
    assert t.flops == 2 * M_ * N_ * K_


def test_hlo_scan_trip_count():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    bs = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)

    def scanned(a, bs):
        def body(c, b):
            return c @ b, ()
        y, _ = jax.lax.scan(body, a, bs)
        return y
    t = analyze(jax.jit(scanned).lower(x, bs).compile().as_text())
    assert t.flops == 6 * 2 * 64 ** 3
    assert 6 in t.trip_counts.values()


def test_hlo_nested_scan():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    bs = jax.ShapeDtypeStruct((4, 32, 32), jnp.float32)

    def nested(a, bs):
        def outer(c, b):
            def inner(c2, _):
                return c2 @ b, ()
            c3, _ = jax.lax.scan(inner, c, jnp.arange(3))
            return c3, ()
        y, _ = jax.lax.scan(outer, a, bs)
        return y
    t = analyze(jax.jit(nested).lower(x, bs).compile().as_text())
    assert t.flops == 12 * 2 * 32 ** 3


# --------------------------------------------------------------------------
# collective schedules (core/topology)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n", [2, 4, 8, 16])
def test_halving_reaches_root(n):
    """Every device's list must reach device 0 through the rounds."""
    reached = {i: {i} for i in range(n)}
    for perm, receivers in topology.halving_rounds(n):
        for src, dst in perm:
            reached[dst] |= reached[src]
    assert reached[0] == set(range(n))
    assert topology.schedule_transfers("halving", n) == n - 1   # Lemma 2


@pytest.mark.parametrize("n", [2, 4, 8])
def test_doubling_all_to_all(n):
    reached = {i: {i} for i in range(n)}
    for perm in topology.doubling_rounds(n):
        new = {i: set(s) for i, s in reached.items()}
        for src, dst in perm:
            new[dst] |= reached[src]
        reached = new
    assert all(reached[i] == set(range(n)) for i in range(n))


@pytest.mark.parametrize("n", [2, 4, 8])
def test_ring_covers(n):
    reached = {i: {i} for i in range(n)}
    relay = {i: {i} for i in range(n)}
    for perm in topology.ring_rounds(n):
        new_relay = {}
        for src, dst in perm:
            new_relay[dst] = relay[src]
        relay = new_relay
        for i in range(n):
            reached[i] |= relay[i]
    assert all(reached[i] == set(range(n)) for i in range(n))


def test_schedule_bytes_model():
    from repro.core.fd import comm_bytes
    # FD moves O(k log n) or O(nk); CN moves O(n * shard)
    assert comm_bytes("fd", 16, 9500, 20) < comm_bytes("cn_star", 16, 9500, 20)
    assert comm_bytes("cn_star", 16, 9500, 20) < comm_bytes("cn", 16, 9500, 20)


# --------------------------------------------------------------------------
# elastic resharding
# --------------------------------------------------------------------------

def test_elastic_mesh_shrink():
    from repro.ckpt.elastic import largest_pow2_leq, make_elastic_mesh
    assert largest_pow2_leq(7) == 4
    mesh = make_elastic_mesh(1, model_size=1)
    assert dict(mesh.shape) == {"data": 1, "model": 1}
