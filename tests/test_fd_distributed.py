"""FD distributed top-k vs CN / CN* and the global oracle — on 8 fake
devices in a subprocess (tests in-process must see 1 device)."""


def test_fd_all_schedules_and_baselines(devices8):
    out = devices8("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.fd import fd_topk, fd_topk_gather
from repro.jaxcompat import make_mesh
mesh = make_mesh((8,), ("model",))
scores = jax.random.normal(jax.random.PRNGKey(3), (2, 1024))
rv, ri = jax.lax.top_k(scores, 20)
for sched in ("halving", "doubling", "ring"):
    fv, fi = fd_topk(scores, 20, mesh, "model", schedule=sched)
    np.testing.assert_allclose(np.asarray(fv), np.asarray(rv), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(fi), np.asarray(ri))
for alg in ("cn", "cn_star"):
    fv, fi = fd_topk(scores, 20, mesh, "model", algorithm=alg)
    np.testing.assert_allclose(np.asarray(fv), np.asarray(rv), atol=1e-6)
# phase-4 gather: only winning rows cross
s1 = jax.random.normal(jax.random.PRNGKey(5), (512,))
rows = jax.random.normal(jax.random.PRNGKey(6), (512, 16))
vals, idx, got = fd_topk_gather(s1, rows, 4, mesh, "model")
ref_v, ref_i = jax.lax.top_k(s1, 4)
np.testing.assert_allclose(np.asarray(vals), np.asarray(ref_v), atol=1e-6)
np.testing.assert_allclose(np.asarray(got), np.asarray(rows)[np.asarray(ref_i)],
                           atol=1e-6)
print("FD_OK")
""")
    assert "FD_OK" in out


def test_fd_with_batch_axes(devices8):
    out = devices8("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.fd import fd_topk
from repro.jaxcompat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
scores = jax.random.normal(jax.random.PRNGKey(0), (4, 512))
fv, fi = fd_topk(scores, 8, mesh, "model", batch_axes=("data",))
rv, ri = jax.lax.top_k(scores, 8)
np.testing.assert_allclose(np.asarray(fv), np.asarray(rv), atol=1e-6)
print("BATCH_OK")
""")
    assert "BATCH_OK" in out


def test_fd_sparse_allreduce(devices8):
    out = devices8("""
import jax, jax.numpy as jnp, numpy as np
from repro.optim.compress import (CompressState, compress_init,
                                  fd_sparse_allreduce, inflate_k)
from repro.jaxcompat import make_mesh
mesh = make_mesh((8,), ("pod",))
# per-pod distinct gradients; sparse mean must converge to dense mean
# with error feedback over rounds
g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 32))}
ef = compress_init(g)
g_hat, ef2 = fd_sparse_allreduce(g, ef, mesh, axis="pod", k_frac=0.05)
assert g_hat["w"].shape == (64, 32)
# conservation: selected + residual == accumulated signal
dense_mean = g["w"]  # identical on every pod -> mean == g
err0 = float(jnp.abs(g_hat["w"] - dense_mean).mean())
# second round sends the residual (error feedback drains)
zero = {"w": jnp.zeros_like(g["w"])}
g_hat2, ef3 = fd_sparse_allreduce(zero, ef2, mesh, axis="pod", k_frac=0.05)
total = g_hat["w"] + g_hat2["w"]
err1 = float(jnp.abs(total - dense_mean).mean())
assert err1 < err0, (err0, err1)
assert inflate_k(20, 0.2) == 25    # Lemma 4: k/(1-P)
print("COMPRESS_OK", err0, err1)
""")
    assert "COMPRESS_OK" in out


def test_serve_step_fd_equals_cn(devices8):
    """The full serving path: FD sampling == CN sampling (same winners)."""
    out = devices8("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config, smoke_config
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.runtime.steps import make_serve_step
cfg = smoke_config(get_config("qwen2-0.5b"))
mesh = make_host_mesh(model=4)
from repro.jaxcompat import use_mesh
ctx = use_mesh(mesh); ctx.__enter__()
params = M.init_params(jax.random.PRNGKey(0), cfg, max_seq=64)
state = M.init_decode_state(cfg, batch=2, s_max=32,
                            cache_dtype=jnp.float32)
tok = jnp.ones((2, 1), jnp.int32)
rng = jax.random.PRNGKey(7)
outs = {}
for alg in ("fd", "cn", "cn_star"):
    step = jax.jit(make_serve_step(cfg, mesh, k=8, algorithm=alg,
                                   batch_axes=("data",)))
    t, _ = step(params, state, tok, rng)
    outs[alg] = np.asarray(t)
np.testing.assert_array_equal(outs["fd"], outs["cn"])
np.testing.assert_array_equal(outs["fd"], outs["cn_star"])
print("SERVE_OK", outs["fd"].ravel().tolist())
""", timeout=600)
    assert "SERVE_OK" in out


def test_fd_gather_batched_queries(devices8):
    """A batch of queries over ONE sharded table: every schedule, plus
    batch sharding over the data axis (phase-4 masked psum per query)."""
    out = devices8("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.fd import fd_topk, fd_topk_gather
from repro.jaxcompat import make_mesh
mesh = make_mesh((8,), ("model",))
s = jax.random.normal(jax.random.PRNGKey(5), (4, 512))
rows = jax.random.normal(jax.random.PRNGKey(6), (512, 16))
rv, ri = jax.lax.top_k(s, 4)
for sched in ("halving", "doubling", "ring"):
    vals, idx, got = fd_topk_gather(s, rows, 4, mesh, "model",
                                    schedule=sched)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rv), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(rows)[np.asarray(ri)], atol=1e-6)
mesh2 = make_mesh((2, 4), ("data", "model"))
s2 = jax.random.normal(jax.random.PRNGKey(7), (4, 512))
rows2 = jax.random.normal(jax.random.PRNGKey(8), (512, 8))
rv2, ri2 = jax.lax.top_k(s2, 6)
vals, idx, got = fd_topk_gather(s2, rows2, 6, mesh2, "model",
                                batch_axes=("data",))
np.testing.assert_allclose(np.asarray(vals), np.asarray(rv2), atol=1e-6)
np.testing.assert_allclose(np.asarray(got),
                           np.asarray(rows2)[np.asarray(ri2)], atol=1e-6)
for sched in ("halving", "doubling", "ring"):
    fv, fi = fd_topk(s2, 6, mesh2, "model", schedule=sched,
                     batch_axes=("data",))
    np.testing.assert_allclose(np.asarray(fv), np.asarray(rv2), atol=1e-6)
print("GATHER_BATCH_OK")
""")
    assert "GATHER_BATCH_OK" in out
