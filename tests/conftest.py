"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see 1 device; multi-device tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves."""
import os
import subprocess
import sys
import textwrap

import pytest


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 8,
                     timeout: int = 420) -> str:
    """Run ``code`` in a subprocess with n fake host devices; returns
    stdout.  Raises on nonzero exit."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if out.returncode != 0:
        raise RuntimeError(f"subprocess failed:\n{out.stdout}\n{out.stderr}")
    return out.stdout


@pytest.fixture(scope="session")
def devices8():
    return lambda code, timeout=420: run_with_devices(code, 8, timeout)
