"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see 1 device; multi-device tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves.

If ``hypothesis`` is not installed (the pinned dev dep may be absent in
hermetic containers), a deterministic mini property-testing stub is
injected into ``sys.modules`` BEFORE test modules import it: ``@given``
re-runs the test over seeded random draws, ``@settings`` caps the
example count, and ``strategies.integers`` is the only strategy the
suite uses.  CI installs the real package via requirements-dev.txt.
"""
import functools
import os
import random
import subprocess
import sys
import textwrap
import types

import pytest

try:                                               # pragma: no cover
    import hypothesis                              # noqa: F401
    # Deterministic CI profile — selected with --hypothesis-profile=ci.
    hypothesis.settings.register_profile(
        "ci", derandomize=True, max_examples=40, deadline=None)
except ImportError:                                # build the stub
    class _Integers:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def draw(self, rnd):
            return rnd.randint(self.lo, self.hi)

    class _settings:
        """Stub settings: decorator + no-op profile registry (the CI
        step passes ``--hypothesis-profile=ci``, which only the real
        package's pytest plugin consumes)."""

        def __init__(self, max_examples=100, deadline=None, **_ignored):
            self.max_examples = max_examples

        def __call__(self, fn):
            fn._stub_max_examples = self.max_examples
            return fn

        @staticmethod
        def register_profile(*_a, **_k):
            pass

        @staticmethod
        def load_profile(*_a, **_k):
            pass

    def _given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_stub_max_examples", 20)
                rnd = random.Random(0xF00D)
                for _ in range(n):
                    draws = {k: s.draw(rnd) for k, s in strats.items()}
                    fn(*args, **draws, **kwargs)
            # pytest must not introspect the original signature, else the
            # drawn parameters look like (missing) fixtures
            del wrapper.__wrapped__
            # shape mimics the real package: plugins (e.g. anyio) peek at
            # ``fn.hypothesis.inner_test``
            wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
            return wrapper
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = lambda min_value=0, max_value=0: _Integers(
        min_value, max_value)
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__stub__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 8,
                     timeout: int = 420) -> str:
    """Run ``code`` in a subprocess with n fake host devices; returns
    stdout.  Raises on nonzero exit."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if out.returncode != 0:
        raise RuntimeError(f"subprocess failed:\n{out.stdout}\n{out.stderr}")
    return out.stdout


@pytest.fixture(scope="session")
def devices8():
    return lambda code, timeout=420: run_with_devices(code, 8, timeout)
