"""Unified engine API (ISSUE 2): QuerySpec + Policy registry + compiled
NetworkPlan across the sim and device backends.

  * every registered policy runs through SimEngine with bit-exact parity
    against the scalar ``run_query_reference`` (shared-stream batch of
    one AND independent streams) and against the legacy shims;
  * the NetworkPlan is cached across ``run`` calls (no BFS /
    edge-mask recompute) without changing a single bit of output;
  * DeviceEngine matches ``fd_topk_gather`` on all three schedules and
    ``fd_topk`` for the CN / CN* baselines.
"""
import dataclasses
import inspect
import math

import numpy as np
import pytest

from repro.engine import (NetworkPlan, Policy, QuerySpec, SimEngine,
                          TopKResult, available_policies, get_policy,
                          policy_from_legacy, register_policy)
from repro.p2psim import (SimParams, barabasi_albert, run_queries,
                          run_query, run_query_reference,
                          run_statistics_heuristic, waxman)

TOP = barabasi_albert(220, m=2, seed=7)
PA = SimParams(seed=11)

STANDARD = [n for n in available_policies() if n != "fd-stats"]


def _legacy_kwargs(pol: Policy) -> dict:
    kw = dict(algorithm=pol.algorithm, strategy=pol.strategy,
              dynamic=pol.dynamic)
    if not math.isinf(pol.lifetime_mean_s):
        kw["lifetime_mean_s"] = pol.lifetime_mean_s
    return kw


# --------------------------------------------------------------------------
# SimEngine parity: every registered policy, both RNG modes
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", STANDARD)
def test_sim_engine_shared_batch_of_one_is_reference(name):
    pol = get_policy(name)
    engine = SimEngine(TOP)
    for origin, seed in ((0, 0), (17, 11)):
        pa = SimParams(seed=seed)
        met, _ = run_query_reference(TOP, origin, pa, **_legacy_kwargs(pol))
        res = engine.run(QuerySpec(origins=(origin,), seed=seed), name)
        assert isinstance(res, TopKResult)
        assert res.backend == "sim" and res.policy == name
        assert res.query_metrics(0, 0) == met


@pytest.mark.parametrize("name", STANDARD)
def test_sim_engine_independent_streams_entrywise_reference(name):
    pol = get_policy(name)
    origins = (0, 9, 9, 41)
    engine = SimEngine(TOP, PA)
    res = engine.run(QuerySpec(origins=origins, n_trials=2,
                               rng="independent"), name)
    for q, o in enumerate(origins):
        for t in range(2):
            met, _ = run_query_reference(
                TOP, o, dataclasses.replace(PA, seed=PA.seed + q * 2 + t),
                **_legacy_kwargs(pol))
            assert res.query_metrics(q, t) == met, (name, q, t)


@pytest.mark.parametrize("name", STANDARD)
def test_sim_engine_matches_legacy_shims(name, monkeypatch):
    monkeypatch.setenv("REPRO_LEGACY_API", "1")   # retired shims re-enabled
    pol = get_policy(name)
    engine = SimEngine(TOP, PA)
    res = engine.run(QuerySpec(origins=(3, 12), n_trials=2), name)
    bm = run_queries(TOP, [3, 12], PA, 2, **_legacy_kwargs(pol))
    for f in ("n_reached", "m_fw", "m_bw", "m_rt", "b_fw", "b_bw", "b_rt",
              "response_time_s", "accuracy"):
        np.testing.assert_array_equal(getattr(res.metrics, f),
                                      getattr(bm, f), err_msg=f)
    # the scalar shim is a batch of ONE (shared stream) over the engine
    one = engine.run(QuerySpec(origins=(3,)), name)
    met, _ = run_query(TOP, 3, PA, **_legacy_kwargs(pol))
    assert one.query_metrics(0, 0) == met


def test_churn_policy_variant_parity():
    pol = get_policy("fd-dynamic").variant(lifetime_mean_s=45.0)
    res = SimEngine(TOP, PA).run(QuerySpec(origins=(0,)), pol)
    met, _ = run_query_reference(TOP, 0, PA, lifetime_mean_s=45.0)
    assert res.query_metrics(0, 0) == met


def test_spec_k_and_explicit_seeds_override():
    seeds = np.array([[101, 202], [303, 404]])
    spec = QuerySpec(origins=(0, 9), n_trials=2, k=7, seeds=seeds)
    assert spec.rng == "independent"          # implied by seeds
    res = SimEngine(TOP, PA).run(spec, "fd-st1+2")
    assert res.k == 7
    for q, o in enumerate((0, 9)):
        for t in range(2):
            met, _ = run_query_reference(
                TOP, o, dataclasses.replace(PA, k=7, seed=int(seeds[q, t])),
                strategy="st1+2", dynamic=False)
            assert res.query_metrics(q, t) == met


# --------------------------------------------------------------------------
# SimEngine(backend="jax"): jitted sweeps, same bits (ISSUE 3)
# --------------------------------------------------------------------------

JTOP = barabasi_albert(96, m=2, seed=3)      # small: keeps jit compiles fast
_PARITY_FIELDS = ("n_reached", "n_edges_pq", "m_fw", "m_bw", "m_rt",
                  "b_fw", "b_bw", "b_rt", "response_time_s", "accuracy")


def _assert_metrics_equal(a, b, msg):
    for f in _PARITY_FIELDS:
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                      err_msg=f"{msg}: {f}")


@pytest.mark.parametrize("name", STANDARD)
def test_jax_backend_bit_exact_all_policies(name):
    """backend="jax" == numpy backend in EVERY rng mode (same draws, same
    sweep results bit-for-bit), and == the scalar reference wherever the
    numpy backend is (shared batch of one, independent streams)."""
    pol = get_policy(name)
    en = SimEngine(JTOP, PA)
    ej = SimEngine(JTOP, PA, backend="jax")
    assert ej.backend == "sim-jax"
    # shared batch of one == scalar reference
    met, _ = run_query_reference(JTOP, 5, SimParams(seed=2),
                                 **_legacy_kwargs(pol))
    res = ej.run(QuerySpec(origins=(5,), seed=2), name)
    assert res.backend == "sim-jax" and res.query_metrics(0, 0) == met
    # independent streams: entry-wise reference parity
    spec = QuerySpec(origins=(0, 7, 7), n_trials=2, rng="independent")
    rj = ej.run(spec, name)
    for q, o in enumerate((0, 7, 7)):
        for t in range(2):
            met, _ = run_query_reference(
                JTOP, o, dataclasses.replace(PA, seed=PA.seed + q * 2 + t),
                **_legacy_kwargs(pol))
            assert rj.query_metrics(q, t) == met, (name, q, t)
    # shared stream, batch > 1: full cross-backend equality
    spec = QuerySpec(origins=(1, 8), n_trials=3)
    _assert_metrics_equal(ej.run(spec, name).metrics,
                          en.run(spec, name).metrics, name)


def test_jax_backend_pallas_kernel_path():
    """use_pallas=True routes every pairwise merge through the Pallas
    bitonic kernel (interpret mode off-TPU) — same bits as the default
    fused-jnp network and the numpy backend."""
    pa = SimParams(seed=4, k=8)
    spec = QuerySpec(origins=(0, 3), n_trials=2)
    rn = SimEngine(JTOP, pa).run(spec, "fd-dynamic")
    rp = SimEngine(JTOP, pa, backend="jax", use_pallas=True).run(
        spec, "fd-dynamic")
    _assert_metrics_equal(rp.metrics, rn.metrics, "pallas")


@pytest.mark.parametrize("name", STANDARD)
def test_jax_backend_churn_bit_exact_all_policies(name):
    """Finite ``lifetime_mean_s`` runs IN the jitted sweep (no numpy
    fallback, asserted via ``backend_used``) and stays bit-exact: ==
    the numpy backend in every rng mode, == the scalar reference
    wherever numpy is (shared batch of one, independent streams)."""
    pol = get_policy(name).variant(lifetime_mean_s=25.0)
    en = SimEngine(JTOP, PA)
    ej = SimEngine(JTOP, PA, backend="jax")
    kw = _legacy_kwargs(pol)
    # shared batch of one == scalar reference, executed on the jax path
    met, _ = run_query_reference(JTOP, 5, SimParams(seed=2), **kw)
    res = ej.run(QuerySpec(origins=(5,), seed=2), pol)
    assert res.backend_used == "sim-jax"          # no silent fallback
    assert res.query_metrics(0, 0) == met
    # independent streams: entry-wise reference parity under churn
    spec = QuerySpec(origins=(0, 7), n_trials=2, rng="independent")
    rj = ej.run(spec, pol)
    assert rj.backend_used == "sim-jax"
    for q, o in enumerate((0, 7)):
        for t in range(2):
            met, _ = run_query_reference(
                JTOP, o, dataclasses.replace(PA, seed=PA.seed + q * 2 + t),
                **kw)
            assert rj.query_metrics(q, t) == met, (name, q, t)
    # shared stream, batch > 1: full cross-backend equality
    spec = QuerySpec(origins=(1, 8), n_trials=3)
    _assert_metrics_equal(ej.run(spec, pol).metrics,
                          en.run(spec, pol).metrics, name)


def test_jax_backend_no_churn_fallback_and_stats_warns_once():
    """Churn executes on the jax path (the old transparent numpy
    fallback is gone); the one remaining fallback — fd-stats — is
    recorded on ``backend_used`` and warned about at most ONCE per
    engine, however many runs hit it."""
    import warnings as _warnings
    ej = SimEngine(JTOP, PA, backend="jax")
    en = SimEngine(JTOP, PA)
    pol = get_policy("fd-dynamic").variant(lifetime_mean_s=30.0)
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")           # churn must NOT warn
        rj = ej.run(QuerySpec(origins=(0,)), pol)
    assert rj.backend == rj.backend_used == "sim-jax"
    assert (rj.query_metrics(0, 0)
            == en.run(QuerySpec(origins=(0,)), pol).query_metrics(0, 0))
    with _warnings.catch_warnings(record=True) as seen:
        _warnings.simplefilter("always")
        rs = ej.run(QuerySpec(origins=(0,)), "fd-stats")
        ej.run(QuerySpec(origins=(0,)), "fd-stats")   # second run: silent
    assert rs.backend == "sim-jax" and rs.backend_used == "sim"
    fallback_warns = [w for w in seen
                      if "numpy reference path" in str(w.message)]
    assert len(fallback_warns) == 1
    rn = en.run(QuerySpec(origins=(0,)), "fd-stats")
    assert rn.backend_used == rn.backend == "sim"     # numpy: no warning
    assert rs.extras["metrics_full"] == rn.extras["metrics_full"]
    assert rs.extras["accuracy"] == rn.extras["accuracy"]


# --------------------------------------------------------------------------
# churn edge cases (§4/§5.4): the scenarios the jitted sweep must nail
# --------------------------------------------------------------------------

def _edges_topology(n, edges):
    from repro.p2psim.graph import Topology
    adj = [set() for _ in range(n)]
    for u, v in edges:
        adj[u].add(v)
        adj[v].add(u)
    return Topology(n, [np.array(sorted(a), np.int32) for a in adj],
                    "test")


# a 5-level tree: levels {0} {1,2} {3,4,5} {6,7,8} {9,10} — small enough
# to scan seeds against the scalar reference, deep enough for reroute
# cascades (grandchildren exist at three levels)
CHURN_TREE = _edges_topology(
    11, [(0, 1), (0, 2), (1, 3), (1, 4), (2, 5), (3, 6), (4, 7), (5, 8),
         (6, 9), (7, 10)])


def _churn_reference(seed, lifetime):
    met, st = run_query_reference(
        CHURN_TREE, 0, SimParams(seed=seed), lifetime_mean_s=lifetime,
        return_state=True)
    dead = {int(v) for v in np.flatnonzero(st["reached"])
            if st["merged_scores"][v] is None}
    return met, st, dead


def test_churn_entire_level_dead_forces_reroute_cascade():
    """An ENTIRE depth level dies before sending: every level-2 list
    must reach the origin through §4.2 rerouting (dead parent ->
    grandparent), and both engine backends must reproduce the scalar
    reference bit-for-bit on that entry."""
    found = None
    for seed in range(500):
        met, st, dead = _churn_reference(seed, 2.5)
        lvl1 = {int(v) for v in np.flatnonzero(st["depth"] == 1)}
        lvl2 = {int(v) for v in np.flatnonzero(st["depth"] == 2)}
        if lvl1 and lvl1 <= dead and (lvl2 - dead):
            found = (seed, met, lvl2 - dead)
            break
    assert found is not None, "no full-level-dead seed found in range"
    seed, met, rerouted = found
    pol = get_policy("fd-dynamic").variant(lifetime_mean_s=2.5)
    spec = QuerySpec(origins=(0,), seed=seed)
    for backend in ("numpy", "jax"):
        res = SimEngine(CHURN_TREE, backend=backend).run(spec, pol)
        assert res.query_metrics(0, 0) == met, backend
    # the surviving level-2 lists were rerouted, not dropped: their
    # owners can only appear in the final list via the dead parent's
    # replacement path
    assert met.m_bw >= len(rerouted)


def test_churn_lifetime_shorter_than_one_hop_wait():
    """lifetime_mean_s far below a single hop's latency: every
    non-origin peer dies before its send time.  The origin is clamped
    immortal in the SHARED draws (the paper's originator waits out its
    own query), answers from its own k-list alone, and all backends
    agree bit-for-bit."""
    from repro.p2psim.simulate import _precompute_draws
    pa = SimParams(seed=3)
    lifetime = 0.01                     # hop latency alone is ~0.2 s
    draws = _precompute_draws(np.array([0]), [pa.seed], CHURN_TREE.n, pa,
                              "fd", "st1+2", lifetime, True)
    assert np.isinf(draws.death[0, 0])            # origin never dies
    assert np.isfinite(draws.death[0, 1:]).all()
    met, st, dead = _churn_reference(pa.seed, lifetime)
    reached = {int(v) for v in np.flatnonzero(st["reached"])}
    assert 0 not in dead and reached - {0} <= dead
    pol = get_policy("fd-dynamic").variant(lifetime_mean_s=lifetime)
    spec = QuerySpec(origins=(0,), seed=pa.seed)
    rj = SimEngine(CHURN_TREE, backend="jax").run(spec, pol)
    rn = SimEngine(CHURN_TREE).run(spec, pol)
    assert rj.query_metrics(0, 0) == met == rn.query_metrics(0, 0)
    assert int(rj.metrics.m_bw[0, 0]) == 0        # nobody lived to send
    # heavy churn must cost accuracy vs the static network
    static, _ = run_query_reference(CHURN_TREE, 0, SimParams(seed=3))
    assert met.accuracy < static.accuracy


def test_jax_backend_nonpow2_k_and_explicit_seeds():
    seeds = np.array([[11, 22], [33, 44]])
    spec = QuerySpec(origins=(0, 9), n_trials=2, k=7, seeds=seeds)
    res = SimEngine(JTOP, PA, backend="jax").run(spec, "fd-st1+2")
    for q, o in enumerate((0, 9)):
        for t in range(2):
            met, _ = run_query_reference(
                JTOP, o,
                dataclasses.replace(PA, k=7, seed=int(seeds[q, t])),
                strategy="st1+2", dynamic=False)
            assert res.query_metrics(q, t) == met


def test_jax_backend_validation_and_plan_sharing():
    with pytest.raises(ValueError):
        SimEngine(JTOP, backend="cuda")
    plan = NetworkPlan(JTOP)
    en = SimEngine(plan, PA)
    ej = SimEngine(plan, PA, backend="jax")
    spec = QuerySpec(origins=(2,))
    _assert_metrics_equal(ej.run(spec).metrics, en.run(spec).metrics,
                          "shared plan")
    assert ej.plan is en.plan is plan
    # the depth slices are compiled once and cached on the shared plan
    assert plan.cache_info()["depth_slices"] >= 1
    n_slices = plan.cache_info()["depth_slices"]
    ej.run(spec)
    assert plan.cache_info()["depth_slices"] == n_slices


# --------------------------------------------------------------------------
# fd-stats policy (two-round statistics heuristic)
# --------------------------------------------------------------------------

def test_fd_stats_policy_matches_legacy_and_reduces_traffic(monkeypatch):
    monkeypatch.setenv("REPRO_LEGACY_API", "1")   # retired shims re-enabled
    engine = SimEngine(TOP, PA)
    res = engine.run(QuerySpec(origins=(0,)),
                     get_policy("fd-stats").variant(z=0.8))
    m1, m2, red, acc = run_statistics_heuristic(TOP, 0, PA, 0.8)
    assert res.extras["metrics_full"] == m1
    assert res.extras["metrics_pruned"] == m2
    assert res.extras["comm_reduction"] == red
    assert res.extras["accuracy"] == acc
    assert res.query_metrics(0, 0) == m2      # metrics = pruned round
    assert red > 0.0 and acc > 0.5
    # the two reference rounds ran against the plan-resolved auto-TTL
    assert engine.plan.cache_info()["auto_ttls"] == 1
    with pytest.raises(ValueError):
        engine.run(QuerySpec(origins=(0, 1)), "fd-stats")
    # an explicit (1, 1) seeds grid selects the entry's RNG stream
    seeded = engine.run(QuerySpec(origins=(0,), seeds=[[42]]), "fd-stats")
    m1s, _, _, _ = run_statistics_heuristic(
        TOP, 0, dataclasses.replace(PA, seed=42), 0.8)
    assert seeded.extras["metrics_full"] == m1s
    with pytest.raises(ValueError):
        engine.run(QuerySpec(origins=(0,), seeds=[[1, 2]]), "fd-stats")


# --------------------------------------------------------------------------
# NetworkPlan caching
# --------------------------------------------------------------------------

def test_network_plan_reused_and_bit_identical():
    engine = SimEngine(TOP, PA)
    spec = QuerySpec(origins=(0, 5, 5), n_trials=2)
    r1 = engine.run(spec)
    cached = engine.plan.cache_info()["origin_statics"]
    assert cached == 2                        # two distinct origins
    r2 = engine.run(spec)
    assert engine.plan.cache_info()["origin_statics"] == cached
    for f in ("m_fw", "m_bw", "b_bw", "b_rt", "response_time_s",
              "accuracy"):
        np.testing.assert_array_equal(getattr(r1.metrics, f),
                                      getattr(r2.metrics, f))
    # cn needs the "basic" forward masks -> new cache entries, same BFS
    engine.run(spec, "cn")
    assert engine.plan.cache_info()["origin_statics"] == 2 * cached
    # warm results still match a cold engine bit-for-bit
    r3 = SimEngine(TOP, PA).run(spec)
    np.testing.assert_array_equal(r2.metrics.response_time_s,
                                  r3.metrics.response_time_s)


def test_plan_is_shareable_and_ttl_param_keyed():
    plan = NetworkPlan(TOP)
    e1 = SimEngine(plan, PA)
    e2 = SimEngine(plan, dataclasses.replace(PA, ttl=3))
    m_auto = e1.run(QuerySpec(origins=(0,))).query_metrics()
    m_ttl3 = e2.run(QuerySpec(origins=(0,))).query_metrics()
    assert e1.plan is e2.plan is plan
    assert m_ttl3.n_reached < m_auto.n_reached        # TTL 3 truncates
    ref, _ = run_query_reference(TOP, 0, dataclasses.replace(PA, ttl=3))
    assert m_ttl3 == ref
    assert plan.auto_ttl(0) == e1.plan._statics[
        (0, 0, "st1+2")].ttl          # resolved once, shared


def test_prepare_required():
    with pytest.raises(RuntimeError):
        SimEngine().run(QuerySpec())


# --------------------------------------------------------------------------
# registry / spec / legacy-kwarg mapping
# --------------------------------------------------------------------------

def test_registry_surface():
    assert set(available_policies()) == {
        "fd-basic", "fd-st1", "fd-st1+2", "fd-dynamic", "cn", "cn-star",
        "fd-stats"}
    with pytest.raises(KeyError):
        get_policy("fd-nope")
    with pytest.raises(ValueError):
        register_policy(Policy("cn", "cn"))
    pol = get_policy("fd-dynamic")
    assert get_policy(pol) is pol             # Policy passes through
    assert pol.variant(lifetime_mean_s=9.0).lifetime_mean_s == 9.0
    assert pol.lifetime_mean_s == math.inf    # variant is a copy


def test_policy_from_legacy_mapping():
    assert policy_from_legacy("fd", "st1+2", True).name == "fd-dynamic"
    assert policy_from_legacy("fd", "st1+2", False).name == "fd-st1+2"
    assert policy_from_legacy("fd", "basic", False).name == "fd-basic"
    assert policy_from_legacy("fd", "st1", False).name == "fd-st1"
    assert policy_from_legacy("cn").name == "cn"
    assert policy_from_legacy("cn_star").name == "cn-star"
    anon = policy_from_legacy("fd", "basic", True)    # no named member
    assert anon.algorithm == "fd" and anon.dynamic
    assert policy_from_legacy(
        "fd", lifetime_mean_s=60.0).lifetime_mean_s == 60.0


def test_query_spec_validation():
    with pytest.raises(ValueError):
        QuerySpec(rng="both")
    with pytest.raises(ValueError):
        QuerySpec(n_trials=0)
    with pytest.raises(ValueError):           # seeds shape mismatch
        SimEngine(TOP).run(QuerySpec(origins=(0,), n_trials=2,
                                     seeds=np.zeros((3, 3), np.int64)))


def test_no_shared_mutable_params_default(monkeypatch):
    # the old ``params: SimParams = SimParams()`` module-level instance
    # was shared across calls; defaults must now be None
    for fn in (run_query, run_queries, run_query_reference):
        assert inspect.signature(fn).parameters["params"].default is None
    monkeypatch.setenv("REPRO_LEGACY_API", "1")   # retired shims re-enabled
    m1, _ = run_query(TOP, 0)
    m2, _ = run_query(TOP, 0)
    assert m1 == m2


def test_waxman_cross_check():
    wax = waxman(120, seed=3)
    engine = SimEngine(wax, PA)
    for name in ("fd-dynamic", "cn-star"):
        res = engine.run(QuerySpec(origins=(1,)), name)
        met, _ = run_query_reference(wax, 1, PA,
                                     **_legacy_kwargs(get_policy(name)))
        assert res.query_metrics(0, 0) == met


# --------------------------------------------------------------------------
# DeviceEngine: same surface over the shard_map collectives
# --------------------------------------------------------------------------

def test_device_engine_matches_fd_collectives(devices8):
    out = devices8("""
import jax, numpy as np
from repro.core.fd import fd_topk, fd_topk_gather
from repro.engine import DeviceEngine, QuerySpec, get_policy
from repro.jaxcompat import make_mesh

mesh = make_mesh((8,), ("model",))
scores = jax.random.normal(jax.random.PRNGKey(3), (2, 1024))
rows = jax.random.normal(jax.random.PRNGKey(6), (1024, 16))
spec = QuerySpec(k=20)
for sched in ("halving", "doubling", "ring"):
    eng = DeviceEngine(mesh, schedule=sched)
    res = eng.run(spec, "fd-dynamic", scores=scores, rows=rows)
    rv, ri, rr = fd_topk_gather(scores, rows, 20, mesh, "model",
                                schedule=sched)
    np.testing.assert_array_equal(np.asarray(res.values), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(res.indices), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(res.rows), np.asarray(rr))
    assert res.backend == "device" and res.extras["model_bytes"] > 0
    # compiled plan reuse: second run hits the cached jitted callable
    n = len(eng._compiled)
    res2 = eng.run(spec, "fd-dynamic", scores=scores, rows=rows)
    assert len(eng._compiled) == n
    np.testing.assert_array_equal(np.asarray(res2.values),
                                  np.asarray(res.values))
eng = DeviceEngine(mesh)
for pol, alg in (("cn", "cn"), ("cn-star", "cn_star")):
    res = eng.run(spec, pol, scores=scores)
    rv, ri = fd_topk(scores, 20, mesh, "model", algorithm=alg)
    np.testing.assert_array_equal(np.asarray(res.values), np.asarray(rv))
# every fd-* policy lowers to the same FD collective
ra = DeviceEngine(mesh).run(spec, "fd-basic", scores=scores)
rb = DeviceEngine(mesh).run(spec, "fd-dynamic", scores=scores)
np.testing.assert_array_equal(np.asarray(ra.values), np.asarray(rb.values))
try:
    eng.run(spec, "fd-stats", scores=scores)
    raise SystemExit("fd-stats must not lower to the device backend")
except ValueError:
    pass
try:
    eng.run(spec, "cn", scores=scores, rows=rows)
    raise SystemExit("gather path must be FD-only")
except ValueError:
    pass
print("DEVICE_ENGINE_OK")
""")
    assert "DEVICE_ENGINE_OK" in out


# --------------------------------------------------------------------------
# shard_map-sharded sim sweep + DeviceEngine precision (ISSUE 10)
# --------------------------------------------------------------------------

def test_sharded_sim_sweep_matches_numpy_bits(devices8):
    """``SimEngine(backend="jax", shard=True)`` partitions the entry
    batch over the device mesh via the jaxcompat shard_map layer and
    must keep the f64 bit contract — and the reduced-precision
    tolerance contract — intact across 8 devices."""
    out = devices8("""
import jax, numpy as np
from repro.engine import SimEngine, QuerySpec
from repro.p2psim import SimParams, barabasi_albert

assert jax.local_device_count() == 8
top = barabasi_albert(150, m=2, seed=3)
p = SimParams(k=5, seed=7)
spec = QuerySpec(origins=(0, 9, 23), n_trials=4, seed=7,
                 rng="independent")           # 12 entries over 8 devices
fields = ("m_fw", "m_bw", "m_rt", "b_fw", "b_bw", "b_rt",
          "response_time_s", "accuracy")
for pol in ("fd-basic", "fd-st1", "fd-dynamic"):
    rn = SimEngine(top, p).run(spec, pol)
    rs = SimEngine(top, p, backend="jax", shard=True).run(spec, pol)
    assert rs.backend_used == "sim-jax", pol
    for f in fields:
        np.testing.assert_array_equal(
            getattr(rn.metrics, f), getattr(rs.metrics, f),
            err_msg=f"shard {pol}: {f}")
rs32 = SimEngine(top, p, backend="jax", shard=True,
                 precision="f32").run(spec, "fd-dynamic")
tol = rs32.extras["tolerance"]
assert tol["ok"], tol
print("SHARDED_SWEEP_OK")
""")
    assert "SHARDED_SWEEP_OK" in out


def test_device_engine_precision_modes(devices8):
    out = devices8("""
import jax, numpy as np
import jax.numpy as jnp
from repro.engine import DeviceEngine, QuerySpec
from repro.jaxcompat import make_mesh

mesh = make_mesh((8,), ("model",))
scores = jax.random.normal(jax.random.PRNGKey(0), (1024,))
spec = QuerySpec(k=10)
res = DeviceEngine(mesh).run(spec, "fd-dynamic", scores=scores)
assert res.precision == "f32"             # caller dtype, honestly reported
rb = DeviceEngine(mesh, precision="bf16").run(spec, "fd-dynamic",
                                              scores=scores)
# the collectives' local top-k computes in f32 (repro.kernels.topk),
# so the bf16 mode quantizes inputs; the requested mode is recorded
assert rb.precision == "bf16" and rb.values.dtype == jnp.float32
# bf16 engine == casting the scores by hand
rc = DeviceEngine(mesh).run(spec, "fd-dynamic",
                            scores=scores.astype(jnp.bfloat16))
np.testing.assert_array_equal(np.asarray(rb.values, np.float32),
                              np.asarray(rc.values, np.float32))
try:
    DeviceEngine(mesh, precision="f8")
    raise SystemExit("bad precision must raise")
except ValueError:
    pass
print("DEVICE_PRECISION_OK")
""")
    assert "DEVICE_PRECISION_OK" in out
