"""NetworkPlan index widths: int32 CSR / depth slices, overflow guards.

The plan's CSR offsets, edge endpoints and depth-slice gather indices
are ``int32`` whenever the plan fits (halved index footprint on
device), ``int64`` on request or when it doesn't.  The guards must be
LOUD: an explicit ``index_dtype="int32"`` on a plan that cannot be
addressed in 32 bits raises a clear error instead of silently
wrapping, and the packed ``edge_keys`` stay int64 unconditionally
(their value space is n², which wraps int32 from n = 46341).
Degenerate shapes — a single isolated peer, a star, a chain at maximum
depth — must run identically under both widths and both backends.
"""
import numpy as np
import pytest

from repro.engine import SimEngine
from repro.engine.api import QuerySpec
from repro.engine.plan import NetworkPlan, resolve_index_dtype
from repro.p2psim.graph import Topology
from repro.p2psim.simulate import SimParams

I32_MAX = np.iinfo(np.int32).max


def _top(adj, kind):
    return Topology(
        n=len(adj),
        neighbors=[np.array(sorted(a), np.int32) for a in adj],
        kind=kind)


def _star(n):
    adj = [set(range(1, n))] + [{0} for _ in range(n - 1)]
    return _top(adj, "star")


def _chain(n):
    adj = [set() for _ in range(n)]
    for i in range(n - 1):
        adj[i].add(i + 1)
        adj[i + 1].add(i)
    return _top(adj, "chain")


# -- resolve_index_dtype guards -------------------------------------------

def test_resolve_auto_picks_narrow_then_wide():
    assert resolve_index_dtype(1000, 4000, "auto") == np.int32
    assert resolve_index_dtype(I32_MAX + 1, 10, "auto") == np.int64
    assert resolve_index_dtype(10, I32_MAX + 1, "auto") == np.int64
    assert resolve_index_dtype(1000, 4000, "int64") == np.int64


@pytest.mark.parametrize("n,nnz", [(I32_MAX + 1, 100),
                                   (100, I32_MAX + 1)])
def test_resolve_int32_overflow_raises_clearly(n, nnz):
    """>2^31 peers or directed edges under an explicit int32 request is
    a clear ValueError naming the quantities — never a silent wrap."""
    with pytest.raises(ValueError) as ei:
        resolve_index_dtype(n, nnz, "int32")
    msg = str(ei.value)
    assert "int32" in msg and "virtual edge space" in msg
    assert str(n) in msg


def test_plan_rejects_bad_dtype_name():
    with pytest.raises(ValueError, match="index_dtype"):
        NetworkPlan(_star(4), index_dtype="int16")


# -- plan array widths ----------------------------------------------------

@pytest.mark.parametrize("req,want", [("auto", np.int32),
                                      ("int32", np.int32),
                                      ("int64", np.int64)])
def test_plan_index_arrays_take_requested_width(req, want):
    plan = NetworkPlan(_star(50), index_dtype=req)
    assert plan.index_dtype == want
    for arr in (plan.indptr, plan.indices, plan.e_src, plan.e_dst):
        assert arr.dtype == want
    # packed keys and message-count accumulators stay wide regardless
    assert plan.edge_keys.dtype == np.int64
    assert plan.degrees.dtype == np.int64
    sts, _ = plan.origin_statics(np.array([0]), plan.auto_ttl(0), "basic")
    sl = plan.depth_slices(sts[0])
    assert sl.index_dtype == want
    for d, lv in enumerate(sl.levels):
        assert lv["vv"].dtype == want
        if d > 0:                          # the root level has no parent
            assert lv["par_pos"].dtype == want


def test_edge_keys_stay_int64_past_the_wrap_point():
    """n = 46342 > sqrt(2^31): a packed int32 key would wrap negative.
    The plan's keys must stay int64, unique and non-negative even on an
    int32-indexed plan."""
    n = 46342
    plan = NetworkPlan(_star(n), index_dtype="int32")
    assert plan.index_dtype == np.int32           # n, nnz both fit
    assert plan.edge_keys.dtype == np.int64
    assert int(plan.edge_keys.max()) > I32_MAX    # would have wrapped
    assert int(plan.edge_keys.min()) >= 0
    assert len(np.unique(plan.edge_keys)) == len(plan.edge_keys)


# -- degenerate shapes under both widths ----------------------------------

def _run(top, index_dtype, backend, policy="fd-dynamic"):
    plan = NetworkPlan(top, index_dtype=index_dtype)
    eng = SimEngine(plan, SimParams(k=3, seed=11), backend=backend)
    return eng.run(QuerySpec(origins=(0,), n_trials=2), policy)


@pytest.mark.parametrize("make,policy", [
    (lambda: _star(9), "fd-dynamic"),
    (lambda: _star(9), "cn"),
    (lambda: _chain(12), "fd-st1"),      # auto-TTL = 11: max depth
    (lambda: _chain(12), "fd-basic"),
])
def test_degenerate_plans_run_identically_both_widths(make, policy):
    runs = {}
    for dt in ("int32", "int64"):
        for backend in ("numpy", "jax"):
            runs[(dt, backend)] = _run(make(), dt, backend, policy)
    base = runs[("int64", "numpy")]
    for key, res in runs.items():
        for f in ("m_fw", "m_bw", "m_rt", "response_time_s", "accuracy"):
            np.testing.assert_array_equal(
                getattr(res.metrics, f), getattr(base.metrics, f),
                err_msg=f"{key} {f}")


def test_single_peer_plan_both_widths():
    """One isolated peer: the origin answers from its own store."""
    for dt in ("int32", "int64"):
        res = _run(_top([set()], "single"), dt, "numpy")
        assert res.k == 3
        assert np.isfinite(res.metrics.response_time_s).all()
